"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype, scale=1.0):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D,bq,bk", [
    (1, 128, 4, 4, 64, 64, 64),      # MHA
    (2, 256, 8, 2, 64, 128, 128),    # GQA 4:1
    (1, 256, 8, 1, 32, 64, 128),     # MQA, uneven blocks
    (1, 512, 2, 2, 128, 256, 256),   # full-size head dim
])
def test_flash_vs_ref(B, S, H, Hkv, D, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, S, H, D), dtype)
    k = rand(ks[1], (B, S, Hkv, D), dtype)
    v = rand(ks[2], (B, S, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, True, 0, bq, bk)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [64, 128])
def test_flash_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    B, S, H, D = 1, 256, 4, 32
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, S, H, D), jnp.float32)
    v = rand(ks[2], (B, S, H, D), jnp.float32)
    out = ops.flash_attention(q, k, v, True, window, 64, 64)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_grad_matches_ref():
    ks = jax.random.split(KEY, 3)
    B, S, H, D = 1, 128, 4, 32
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, S, H, D), jnp.float32)
    v = rand(ks[2], (B, S, H, D), jnp.float32)

    g1 = jax.grad(lambda *a: (ops.flash_attention(*a, True, 0, 64, 64)
                              ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (ref.attention_ref(*a, causal=True)
                              ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_flash_in_model_attention_block():
    """use_flash_kernel=True path through models.transformer training."""
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("qwen2-1.5b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.ones((2, 64), jnp.int32)
    opts_k = T.ModelOptions(q_chunk=32, kv_chunk=32, loss_chunk=32,
                            use_flash_kernel=True)
    opts_j = T.ModelOptions(q_chunk=32, kv_chunk=32, loss_chunk=32)
    yk, _ = T.forward(params, cfg, tokens, opts=opts_k)
    yj, _ = T.forward(params, cfg, tokens, opts=opts_j)
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yj, np.float32),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,nh,hd,st_,chunk", [
    (1, 128, 2, 16, 16, 64),
    (2, 256, 4, 32, 16, 128),
    (1, 256, 1, 64, 32, 256),   # single head, chunk == S
])
def test_ssm_vs_ref(B, S, nh, hd, st_, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    xv = rand(ks[0], (B, S, nh, hd), dtype, 0.5)
    ld = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    Bm = rand(ks[2], (B, S, st_), dtype, 0.3)
    Cm = rand(ks[3], (B, S, st_), dtype, 0.3)
    h0 = jax.random.normal(ks[4], (B, nh, hd, st_), jnp.float32) * 0.1
    y, h = ops.ssm_scan(xv, ld, Bm, Cm, h0, chunk)
    yr, hr = ref.ssm_scan_ref(xv, ld, Bm, Cm, h0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssm_no_h0():
    ks = jax.random.split(KEY, 4)
    B, S, nh, hd, st_ = 1, 128, 2, 16, 8
    xv = rand(ks[0], (B, S, nh, hd), jnp.float32, 0.5)
    ld = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    Bm = rand(ks[2], (B, S, st_), jnp.float32, 0.3)
    Cm = rand(ks[3], (B, S, st_), jnp.float32, 0.3)
    y, h = ops.ssm_scan(xv, ld, Bm, Cm, None, 64)
    yr, hr = ref.ssm_scan_ref(xv, ld, Bm, Cm, None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_ssm_grads_match_ref():
    ks = jax.random.split(KEY, 5)
    B, S, nh, hd, st_ = 1, 128, 2, 8, 8
    xv = rand(ks[0], (B, S, nh, hd), jnp.float32, 0.5)
    ld = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    Bm = rand(ks[2], (B, S, st_), jnp.float32, 0.3)
    Cm = rand(ks[3], (B, S, st_), jnp.float32, 0.3)
    h0 = jax.random.normal(ks[4], (B, nh, hd, st_), jnp.float32) * 0.1
    g1 = jax.grad(lambda *a: (ops.ssm_scan(*a, 64)[0] ** 2).sum(),
                  argnums=(0, 1, 2, 3, 4))(xv, ld, Bm, Cm, h0)
    g2 = jax.grad(lambda *a: (ref.ssm_scan_ref(*a)[0] ** 2).sum(),
                  argnums=(0, 1, 2, 3, 4))(xv, ld, Bm, Cm, h0)
    for a, b, n in zip(g1, g2, ["xv", "ld", "B", "C", "h0"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4, err_msg=n)


def test_ssm_kernel_in_mamba_forward():
    from repro.models import ssm
    ks = jax.random.split(KEY, 2)
    p = ssm.init_ssm_params(ks[0], 32, 2, 8, 8, jnp.float32)
    x = jax.random.normal(ks[1], (2, 64, 32)) * 0.1
    yk, _ = ssm.mamba_forward(p, x, n_heads=2, head_dim=8, state=8,
                              chunk=32, use_kernel=True)
    yj, _ = ssm.mamba_forward(p, x, n_heads=2, head_dim=8, state=8,
                              chunk=32, use_kernel=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yj),
                               rtol=1e-4, atol=1e-4)
