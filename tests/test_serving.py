"""ISSUE 7: the always-on serving profiler.

Pins the tentpole contracts: per-request/per-phase window identities are
ordinary host frames and stay byte-deterministic through ``aggregate()``
and ``merge_databases``; the overhead governor's control law (step-down,
patience-gated step-up, backpressure shed, floor clamp) and its
convergence under real dispatch load; telemetry snapshots round-trip
through the fleet daemon exactly once (duplicate redelivery dedups,
re-export conflicts quarantine); and backpressure flows daemon ->
transport -> producer -> governor over both transports.
"""
import os
import shutil
import time

import numpy as np
import pytest

from repro.core.aggregate import aggregate
from repro.core.merge import merge_databases
from repro.fleet.client import (DirectoryTransport, ShardProducer,
                                SocketTransport, TransportError)
from repro.fleet.daemon import FleetDaemon, SocketIngest
from repro.serving.governor import (GovernorConfig, LEVELS,
                                    OverheadGovernor)
from repro.serving.live import ServingProfiler
from repro.serving.stats import ServingStats
from repro.serving.telemetry import (SERVING_METRICS, TelemetryExporter,
                                     read_telemetry)
from repro.serving.window import (DECODE, PREFILL, WINDOW_MODULE,
                                  request_frames, window_label)
from repro.traceview.stats import (request_attribution,
                                   request_latency_percentiles,
                                   window_labels)
from repro.traceview.tracedb import TraceDB

from test_merge import assert_db_identical, db_bytes

FLOOR = len(LEVELS) - 1


def _spin(ns):
    end = time.perf_counter_ns() + ns
    while time.perf_counter_ns() < end:
        pass


def serve_run(out_dir, n_requests=3, gen_len=2, rid_prefix="r", **kw):
    """A small synthetic serving run; returns (profile paths, traces)."""
    sp = ServingProfiler(str(out_dir), **kw)
    with sp:
        for i in range(n_requests):
            with sp.request(f"{rid_prefix}{i}", PREFILL, tokens=8):
                with sp.profiler.dispatch("kernel", "prefill", stream=0):
                    _spin(200_000)
            for _ in range(gen_len):
                with sp.request(f"{rid_prefix}{i}", DECODE, tokens=1):
                    with sp.profiler.dispatch("kernel", "decode",
                                              stream=0):
                        _spin(100_000)
        sp.profiler.flush()
        paths = sp.write()
    # pair each profile with its trace via the write() key scheme
    # (cpu_N <-> cpu_trace_N, gpu_S <-> gpu_trace_S)
    pairs = []
    for k in sorted(paths):
        if "trace" in k:
            continue
        fam, idx = k.rsplit("_", 1)
        pairs.append((paths[k], paths.get(f"{fam}_trace_{idx}")))
    profs = [p for p, _ in pairs]
    traces = [t for _, t in pairs if t]
    return sp, profs, traces, dict(pairs)


# ---------------------------------------------------------------------------
# Window identities
# ---------------------------------------------------------------------------
def test_window_frames_roundtrip():
    req, ph = request_frames("r7", DECODE)
    assert req.module == ph.module == WINDOW_MODULE
    assert window_label(req) == ("r7", None)
    assert window_label(ph) == (None, DECODE)
    (only,) = request_frames("r7")
    assert window_label(only) == ("r7", None)
    # non-window frames decode to (None, None)
    from repro.core.cct import Frame, HOST
    assert window_label(Frame(HOST, "request:r7", "app.py", 0)) == \
        (None, None)


def test_windows_survive_aggregation(tmp_path):
    _, profs, traces, _ = serve_run(tmp_path / "run", governor=False)
    db = aggregate(profs, str(tmp_path / "db"), n_ranks=1, n_threads=1,
                   trace_paths=traces)
    window_frames = [f for f in db.frames if f.module == WINDOW_MODULE]
    names = {f.name for f in window_frames}
    assert {"request:r0", "request:r1", "request:r2",
            "phase:prefill", "phase:decode"} <= names
    req, ph = window_labels(db)
    assert {r for r in req if r} == {"r0", "r1", "r2"}
    assert {p for p in ph if p} == {PREFILL, DECODE}
    # a phase ctx always sits inside its request window
    assert all(r is not None for r, p in zip(req, ph) if p is not None)


def test_windows_byte_deterministic_through_merge(tmp_path):
    """The tentpole invariant: request windows are ordinary frames, so
    the canonical-database contract holds unchanged — a one-shot
    aggregate of a windowed run is byte-identical to a sharded
    aggregate + merge of the same profiles."""
    # two serving "hosts" (ranks): the fleet's real sharding unit — a
    # gpu trace maps its contexts through its own rank's host profile,
    # so a shard always carries a rank's full profile family
    runs = [serve_run(tmp_path / f"run{r}", n_requests=3, rank=r,
                      rid_prefix=f"h{r}-r", governor=False)
            for r in range(2)]
    profs = [p for _, ps, _, _ in runs for p in ps]
    traces = [t for _, _, ts, _ in runs for t in ts]
    one = str(tmp_path / "one")
    aggregate(profs, one, trace_paths=traces)
    shards = []
    for i, (_, ps, ts, _) in enumerate(runs):
        d = str(tmp_path / f"shard{i}")
        aggregate(ps, d, trace_paths=ts)
        shards.append(d)
    merged = str(tmp_path / "merged")
    merge_databases(shards, merged)
    assert_db_identical(merged, one)
    # and shard order is irrelevant, windows or not
    again = str(tmp_path / "again")
    merge_databases(list(reversed(shards)), again)
    assert db_bytes(again) == db_bytes(merged)


def test_request_attribution_from_database(tmp_path):
    _, profs, traces, _ = serve_run(tmp_path / "run", n_requests=3,
                                    gen_len=2, governor=False)
    db = aggregate(profs, str(tmp_path / "db"), n_ranks=1, n_threads=1,
                   trace_paths=traces)
    lines = TraceDB(db.trace_db_path()).line_views()
    rows = request_attribution(lines, db)
    assert {r[0] for r in rows} == {"r0", "r1", "r2"}
    for _, total, phases in rows:
        assert total > 0
        assert phases.get(PREFILL, 0) > 0 and phases.get(DECODE, 0) > 0
    pct = request_latency_percentiles(lines, db)
    # spans cover the whole phase: prefill >= its 200us spin, the decode
    # phase >= its gen_len x 100us spins
    assert pct[PREFILL][50.0] >= 0.2
    assert pct[DECODE][50.0] >= 0.2
    assert pct[PREFILL][99.0] >= pct[PREFILL][50.0]


# ---------------------------------------------------------------------------
# Governor control law (scripted stub profiler: pure feedback logic)
# ---------------------------------------------------------------------------
class StubProfiler:
    def __init__(self):
        self.sample_scale = None
        self.sample_cap = None
        self.unwind_depth = None
        self.c = {"dispatches": 0, "tool_ns": 0, "app_ns": 0}

    def overhead_counters(self):
        return dict(self.c)

    def window(self, n, frac):
        """Advance n dispatches at the given tool/app overhead."""
        self.c["dispatches"] += n
        self.c["app_ns"] += n * 1_000_000
        self.c["tool_ns"] += int(n * 1_000_000 * frac)


def make_gov(**cfg):
    prof = StubProfiler()
    gov = OverheadGovernor(prof, GovernorConfig(
        budget=0.10, headroom=0.5, interval=4, patience=2, **cfg))
    return prof, gov


def test_governor_applies_knobs_on_init():
    prof, gov = make_gov()
    lv = LEVELS[0]
    assert (prof.sample_scale, prof.sample_cap, prof.unwind_depth) == \
        (lv.sample_scale, lv.sample_cap, lv.unwind_depth)


def test_governor_steps_down_when_over_budget():
    prof, gov = make_gov()
    prof.window(4, 0.5)                  # way over 0.10
    d = gov.observe()
    assert d is not None and d.level == 1 and gov.throttle_downs == 1
    lv = LEVELS[1]
    assert (prof.sample_scale, prof.sample_cap, prof.unwind_depth) == \
        (lv.sample_scale, lv.sample_cap, lv.unwind_depth)


def test_governor_no_decision_before_interval():
    prof, gov = make_gov()
    prof.window(3, 0.5)                  # < interval dispatches
    assert gov.observe() is None and gov.level == 0


def test_governor_clamps_at_floor():
    prof, gov = make_gov()
    for _ in range(FLOOR + 3):           # more over-budget windows than rungs
        prof.window(4, 0.9)
        gov.observe()
    assert gov.level == FLOOR
    assert gov.throttle_downs == FLOOR   # clamped steps don't count
    assert LEVELS[FLOOR].sample_scale == 0.0   # floor still measures: the
    assert LEVELS[FLOOR].sample_cap == 1       # never-off contract


def test_governor_patience_gates_step_up():
    prof, gov = make_gov()
    prof.window(4, 0.5)
    gov.observe()                        # down to 1
    prof.window(4, 0.01)                 # low window #1: no step yet
    gov.observe()
    assert gov.level == 1
    prof.window(4, 0.01)                 # low window #2 == patience
    gov.observe()
    assert gov.level == 0 and gov.throttle_ups == 1


def test_governor_midband_resets_streak():
    prof, gov = make_gov()
    prof.window(4, 0.5)
    gov.observe()                        # down to 1
    prof.window(4, 0.01)                 # low #1
    gov.observe()
    prof.window(4, 0.08)                 # in (headroom*budget, budget]: hold
    gov.observe()
    prof.window(4, 0.01)                 # low #1 again — streak was reset
    gov.observe()
    assert gov.level == 1


def test_governor_backpressure_sheds_and_blocks_step_up():
    prof, gov = make_gov()
    gov.note_backpressure(True)          # shed one level on transition
    assert gov.level == 1 and gov.throttle_downs == 1
    gov.note_backpressure(True)          # steady state: no further shed
    assert gov.level == 1
    for _ in range(4):                   # low windows can't raise fidelity
        prof.window(4, 0.01)
        gov.observe()
    assert gov.level == 1
    gov.note_backpressure(False)         # released: patience applies again
    for _ in range(2):
        prof.window(4, 0.01)
        gov.observe()
    assert gov.level == 0


def test_governor_state_surface():
    prof, gov = make_gov()
    prof.window(4, 0.5)
    gov.observe()
    st = gov.state()
    assert st["level"] == 1 and st["level_name"] == LEVELS[1].name
    assert st["decisions"] == 1 and st["overhead"] == pytest.approx(0.5)
    assert st["budget"] == pytest.approx(0.10)


def test_governor_converges_under_real_load(tmp_path):
    """Against a real profiler and an unreachable budget the controller
    must walk the whole ladder to the floor; with a generous budget it
    must hold full fidelity."""
    sp = ServingProfiler(str(tmp_path / "tight"),
                         governor=GovernorConfig(budget=0.001, interval=4),
                         sample_rate_hz=1e6)
    with sp:
        for i in range(12 * len(LEVELS)):
            with sp.request(f"r{i}", DECODE, tokens=1):
                with sp.profiler.dispatch("kernel", "step", stream=0):
                    _spin(50_000)
    assert sp.governor.level == FLOOR
    assert sp.governor.throttle_downs >= FLOOR
    # generous: dispatch cost against 2ms spins sits far below 500%
    sp2 = ServingProfiler(str(tmp_path / "loose"),
                          governor=GovernorConfig(budget=5.0, interval=4),
                          sample_rate_hz=1e6)
    with sp2:
        for i in range(16):
            with sp2.request(f"r{i}", DECODE, tokens=1):
                with sp2.profiler.dispatch("kernel", "step", stream=0):
                    _spin(2_000_000)
    assert sp2.governor.level == 0 and sp2.governor.throttle_downs == 0


# ---------------------------------------------------------------------------
# SLO shed (ISSUE 10 satellite): p99 degradation beats the budget check
# ---------------------------------------------------------------------------
def test_governor_slo_sheds_under_budget():
    """Windows are comfortably under budget, but the serving p99 blows
    past the rolling baseline: the governor must shed anyway, keep
    shedding while degraded, never let the incident poison the
    baseline, and refuse to raise fidelity until the p99 recovers."""
    prof, gov = make_gov()
    for _ in range(3):                   # healthy windows seed the EMA
        prof.window(4, 0.01)
        gov.observe(p99_ms=10.0)
    assert gov.level == 0 and gov.slo_baseline_ms == pytest.approx(10.0)
    prof.window(4, 0.01)                 # under budget, p99 3x baseline
    gov.observe(p99_ms=30.0)
    assert gov.level == 1 and gov.slo_sheds == 1 and gov.slo_degraded
    prof.window(4, 0.01)                 # still degraded: keeps shedding
    gov.observe(p99_ms=30.0)
    assert gov.level == 2 and gov.slo_sheds == 2
    assert gov.slo_baseline_ms == pytest.approx(10.0)   # unpoisoned
    st = gov.state()
    assert st["slo_degraded"] and st["slo_sheds"] == 2
    assert st["slo_baseline_ms"] == pytest.approx(10.0)


def test_governor_slo_recovery_restores_step_up():
    prof, gov = make_gov()
    for _ in range(2):
        prof.window(4, 0.01)
        gov.observe(p99_ms=10.0)
    prof.window(4, 0.01)
    gov.observe(p99_ms=40.0)             # shed to 1
    # degraded blocks step-up even through low windows with no p99
    # signal (the verdict stands until a healthy p99 clears it)
    for _ in range(3):
        prof.window(4, 0.01)
        gov.observe()
    assert gov.level == min(1 + 3, FLOOR)         # kept shedding, never rose
    level_during_incident = gov.level
    # recovery: healthy p99 clears the flag; patience applies as usual
    prof.window(4, 0.01)
    gov.observe(p99_ms=10.0)
    assert not gov.slo_degraded and gov.level == level_during_incident
    prof.window(4, 0.01)
    gov.observe(p99_ms=10.0)             # low streak == patience: step up
    assert gov.level == level_during_incident - 1


def test_governor_slo_converges_to_floor_under_persistent_degradation():
    """Convergence: a p99 that stays degraded regardless of fidelity
    walks the ladder to the floor and holds there — it never oscillates
    back up and never steps below the floor."""
    prof, gov = make_gov()
    prof.window(4, 0.01)
    gov.observe(p99_ms=10.0)             # baseline
    levels = []
    for _ in range(3 * len(LEVELS)):
        prof.window(4, 0.01)
        gov.observe(p99_ms=100.0)
        levels.append(gov.level)
    assert gov.level == FLOOR
    assert levels == sorted(levels)      # monotone walk down, no hunting
    assert gov.slo_baseline_ms == pytest.approx(10.0)
    # identical hysteresis: the budget path's counters are untouched
    assert gov.throttle_ups == 0


def test_governor_slo_baseline_tracks_slow_drift():
    """A gradual p99 drift inside the degradation band is the new
    normal: the EMA follows it and no shed fires."""
    prof, gov = make_gov()
    p99 = 10.0
    for _ in range(10):
        prof.window(4, 0.2)              # over budget: sheds on budget
        gov.observe(p99_ms=p99)
        p99 *= 1.1                       # EMA lag keeps p99/baseline < 1.5
    assert gov.slo_sheds == 0
    assert gov.slo_baseline_ms > 10.0


def test_governor_p99_none_is_pure_budget_control():
    """No latency signal ever: behavior is the pre-SLO control law."""
    prof, gov = make_gov()
    prof.window(4, 0.5)
    gov.observe()
    assert gov.level == 1 and gov.slo_sheds == 0
    assert gov.slo_baseline_ms is None
    for _ in range(2):
        prof.window(4, 0.01)
        gov.observe()
    assert gov.level == 0


def test_governor_config_validates_slo_knobs():
    with pytest.raises(ValueError):
        GovernorConfig(slo_degradation=0.0)
    with pytest.raises(ValueError):
        GovernorConfig(slo_alpha=0.0)
    with pytest.raises(ValueError):
        GovernorConfig(slo_alpha=1.5)


# ---------------------------------------------------------------------------
# ServingStats
# ---------------------------------------------------------------------------
def test_serving_stats_rolling_window():
    t = [0.0]
    st = ServingStats(window_s=10.0, clock=lambda: t[0])
    for i in range(4):
        st.record(f"r{i}", PREFILL, 4_000_000, tokens=8)
        st.record(f"r{i}", DECODE, 1_000_000, tokens=1)
        t[0] += 1.0
    assert st.requests_in_window() == 4
    assert st.percentile_ms(PREFILL, 50) == pytest.approx(4.0)
    assert st.percentile_ms(DECODE, 50) == pytest.approx(1.0)
    assert st.tok_s() == pytest.approx(36 / 3.0)
    t[0] += 100.0                        # everything ages out
    assert st.requests_in_window() == 0
    assert st.percentile_ms(PREFILL, 50) == 0.0


def test_serving_stats_snapshot_matches_telemetry_columns():
    st = ServingStats()
    st.record("r0", PREFILL, 2_000_000, tokens=4)
    snap = st.snapshot()
    assert set(SERVING_METRICS) <= set(snap)
    assert all(isinstance(v, float) for v in snap.values())


# ---------------------------------------------------------------------------
# Telemetry round trip: exactly-once through the fleet daemon
# ---------------------------------------------------------------------------
def fleet_fixture(tmp_path, **producer_kw):
    daemon = FleetDaemon(str(tmp_path / "fleet"), str(tmp_path / "spool"))
    producer = ShardProducer(str(tmp_path / "outbox"),
                             DirectoryTransport(daemon.incoming_dir),
                             producer="hostA", sleep=lambda s: None,
                             **producer_kw)
    return daemon, producer


def snap_for(epoch):
    return {"requests": 2.0, "tokens": 16.0, "tok_s": 100.0 + epoch,
            "decode_p50_ms": 1.5, "governor_level": 2.0}


def test_telemetry_roundtrips_exactly_once(tmp_path):
    daemon, producer = fleet_fixture(tmp_path)
    exporter = TelemetryExporter(producer, host="hostA", rank=0)
    for e in range(3):
        exporter.export(snap_for(e))
    r = daemon.poll_once()
    assert len(r.applied) == 3 and not r.quarantined
    rows = read_telemetry(daemon.database())
    assert [row["epoch"] for row in rows] == [0.0, 1.0, 2.0]
    assert [row["tok_s"] for row in rows] == [100.0, 101.0, 102.0]
    assert rows[0]["host"] == "hostA"
    # unset columns surface as 0.0, not missing
    assert rows[0]["prefill_p99_ms"] == 0.0


def test_telemetry_duplicate_redelivery_dedups(tmp_path):
    daemon, producer = fleet_fixture(tmp_path)
    exporter = TelemetryExporter(producer, host="hostA", rank=0,
                                 deliver=False)
    exporter.export(snap_for(0))
    (env,) = producer.spooled()
    dup = str(tmp_path / "dup.shard")
    shutil.copy(env, dup)
    producer.deliver()
    daemon.poll_once()
    # the crash-redelivery path: the exact same envelope arrives again
    shutil.copy(dup, os.path.join(daemon.incoming_dir,
                                  os.path.basename(env)))
    r = daemon.poll_once()
    assert r.duplicates and not r.applied and not r.quarantined
    assert len(read_telemetry(daemon.database())) == 1


def test_telemetry_reexported_epoch_quarantines(tmp_path):
    """Same (host, rank, epoch), different payload: the deterministic
    shard id turns a double-export into a visible journal conflict, and
    the folded series keeps the first value."""
    daemon, producer = fleet_fixture(tmp_path)
    exporter = TelemetryExporter(producer, host="hostA", rank=0)
    exporter.export(snap_for(0))
    daemon.poll_once()
    exporter.export({"tok_s": 999.0}, epoch=0)      # re-export epoch 0
    r = daemon.poll_once()
    assert len(r.quarantined) == 1
    assert "different payload" in r.quarantined[0][1]
    rows = read_telemetry(daemon.database())
    assert len(rows) == 1 and rows[0]["tok_s"] == 100.0


def test_telemetry_shard_id_is_deterministic():
    exporter = TelemetryExporter(object(), host="node-3.rack/7", rank=2)
    sid = exporter.shard_id(5)
    assert sid == exporter.shard_id(5)
    assert "/" not in sid and sid.endswith("-r2-e00000005")


# ---------------------------------------------------------------------------
# Backpressure: daemon -> transport -> producer -> governor
# ---------------------------------------------------------------------------
def test_directory_backpressure_follows_daemon_spool(tmp_path):
    daemon, producer = fleet_fixture(tmp_path, daemon_spool_soft=2)
    exporter = TelemetryExporter(producer, host="hostA", rank=0)
    for e in range(4):                   # delivered but not yet folded
        exporter.export(snap_for(e))
    assert producer.poll_backpressure() is True
    assert producer.daemon_spool_depth == 4
    gov = OverheadGovernor(StubProfiler(), GovernorConfig(budget=0.1))
    gov.note_backpressure(producer.throttled)
    assert gov.level == 1                # shed on transition
    daemon.poll_once()                   # daemon drains its spool
    assert producer.poll_backpressure() is False
    assert daemon.spool_depth() == 0


def test_socket_backpressure_poll(tmp_path):
    daemon, _ = fleet_fixture(tmp_path)
    sock = str(tmp_path / "fleet.sock")
    listener = SocketIngest(daemon, sock)
    listener.start()
    try:
        transport = SocketTransport(sock)
        producer = ShardProducer(str(tmp_path / "outbox2"), transport,
                                 producer="hostB", daemon_spool_soft=1,
                                 sleep=lambda s: None)
        exporter = TelemetryExporter(producer, host="hostB", rank=1)
        for e in range(3):
            exporter.export(snap_for(e))
        assert transport.poll_status()["spool_depth"] == 3
        assert producer.poll_backpressure() is True
        daemon.poll_once()
        assert producer.poll_backpressure() is False
    finally:
        listener.stop()


def test_stage_outbox_fill_sheds_daemonless(tmp_path):
    """Regression (ISSUE 10 satellite): a producer that only *stages* —
    no deliver loop, no daemon, no explicit poll — must still see its
    own outbox filling, so the governor sheds before the exporter keeps
    writing full-fidelity measurements into a pipe nothing drains."""
    class DeadTransport:                 # no poll_status, send never works
        def send(self, path):
            raise TransportError("daemon is gone")

    src = tmp_path / "db"
    src.mkdir()
    (src / "meta.json").write_text("{}")
    producer = ShardProducer(str(tmp_path / "outbox"), DeadTransport(),
                             spool_soft=2, sleep=lambda s: None)
    gov = OverheadGovernor(StubProfiler(), GovernorConfig(budget=0.10))
    for e in range(4):
        (src / "payload.bin").write_bytes(b"x%d" % e)   # distinct shards
        producer.stage(str(src), epoch=e)
        gov.note_backpressure(producer.throttled)
    assert producer.throttled            # 4 spooled > soft bound 2
    assert gov.level == 1 and gov.throttle_downs == 1


def test_stage_polls_daemon_backpressure(tmp_path):
    """The bugfix proper: ``stage()`` must call ``poll_backpressure``
    (not just the local bound check), so a stage-only producer observes
    the *daemon's* backlog the moment it enqueues."""
    class CountingTransport:
        def __init__(self):
            self.polls = 0

        def send(self, path):
            raise TransportError("unused")

        def poll_status(self):
            self.polls += 1
            return {"spool_depth": 7}

    src = tmp_path / "db"
    src.mkdir()
    (src / "meta.json").write_text("{}")
    transport = CountingTransport()
    producer = ShardProducer(str(tmp_path / "outbox"), transport,
                             spool_soft=32, daemon_spool_soft=3,
                             sleep=lambda s: None)
    producer.stage(str(src), epoch=0)
    assert transport.polls == 1          # polled on the enqueue itself
    assert producer.daemon_backpressured and producer.throttled
    assert producer.daemon_spool_depth == 7


# ---------------------------------------------------------------------------
# ServingProfiler integration: status + periodic export
# ---------------------------------------------------------------------------
def test_serving_profiler_status_and_periodic_export(tmp_path):
    daemon, producer = fleet_fixture(tmp_path)
    sp = ServingProfiler(str(tmp_path / "run"), producer=producer,
                         export_every_s=0.0, governor=True)
    with sp:
        for i in range(3):
            with sp.request(f"r{i}", PREFILL, tokens=4):
                with sp.profiler.dispatch("kernel", "prefill", stream=0):
                    _spin(100_000)
    status = sp.status()
    assert set(SERVING_METRICS) <= set(status)
    assert status["requests"] == 3.0
    assert status["epochs_exported"] >= 3.0
    assert status["prefill_p50_ms"] > 0
    daemon.poll_once()
    rows = read_telemetry(daemon.database())
    assert len(rows) == int(status["epochs_exported"])
    assert [row["epoch"] for row in rows] == \
        sorted(row["epoch"] for row in rows)


# ---------------------------------------------------------------------------
# Overlapping windows (continuous batching): per-dispatch stamping
# ---------------------------------------------------------------------------
def test_overlapping_windows_attribute_exactly_once(tmp_path):
    """Regression (ISSUE 8): a continuous-batching scheduler holds many
    requests' windows open at once and interleaves their decode steps on
    one thread.  The whole-extent ``with`` splice would stack both
    windows (every dispatch lands in both requests — double counted);
    per-dispatch ``step()`` stamping must attribute each dispatch to
    exactly one request, so ``request_attribution`` sums exactly to the
    partition's total GPU busy ns."""
    from repro.core.profiler import Profiler
    from repro.serving.window import RequestWindow

    prof = Profiler(str(tmp_path / "run"), tracing=True, unwind=False)
    w1 = RequestWindow(prof, "r1", DECODE)
    w2 = RequestWindow(prof, "r2", DECODE)
    with prof:
        w1.open()
        with w1.step(PREFILL):           # r1 prefills alone
            with prof.dispatch("kernel", "prefill", stream=0):
                _spin(200_000)
        w2.open()                        # r2 joins the batch mid-flight
        for _ in range(3):               # interleaved decode steps
            with w1.step():
                with prof.dispatch("kernel", "decode", stream=0):
                    _spin(100_000)
            with w2.step():
                with prof.dispatch("kernel", "decode", stream=0):
                    _spin(100_000)
        w1.close()
        w2.close()
        prof.flush()
        paths = prof.write()
    # both spans overlap (that's the point) and each covers its steps
    assert w1.duration_ns > w2.duration_ns > 0
    profs = [p for k, p in sorted(paths.items()) if "trace" not in k]
    traces = [p for k, p in sorted(paths.items()) if "trace" in k]
    db = aggregate(profs, str(tmp_path / "db"), n_ranks=1, n_threads=1,
                   trace_paths=traces)
    lines = TraceDB(db.trace_db_path()).line_views()
    gpu = [td for td in lines if td.identity.get("type") == "gpu"]
    total_gpu_ns = sum(float((td.ends - td.starts).sum()) for td in gpu)
    assert total_gpu_ns > 0
    rows = request_attribution(lines, db)
    assert {r[0] for r in rows} == {"r1", "r2"}
    by_rid = {r[0]: r for r in rows}
    # exactly-once: the per-request split partitions the GPU total
    assert sum(total for _, total, _ in rows) == \
        pytest.approx(total_gpu_ns, rel=1e-9)
    # r1 carries the prefill + its decodes; r2 decodes only
    assert by_rid["r1"][2].get(PREFILL, 0) > 0
    assert by_rid["r1"][2].get(DECODE, 0) > 0
    assert set(by_rid["r2"][2]) == {DECODE}
    # decode work is symmetric across the batch (same spins)
    assert by_rid["r1"][2][DECODE] == \
        pytest.approx(by_rid["r2"][2][DECODE], rel=0.5)


def test_window_exclusive_replaces_not_nests(tmp_path):
    """``Profiler.window_exclusive`` swaps the thread's window stack for
    its body and restores it after — dispatches inside a step carry only
    that request's frames even under a live ``with``-style window."""
    from repro.core.profiler import Profiler
    from repro.serving.window import RequestWindow

    prof = Profiler(str(tmp_path / "run"), tracing=True, unwind=False)
    with prof:
        with RequestWindow(prof, "outer", DECODE):
            w = RequestWindow(prof, "inner", DECODE)
            with w.step():
                with prof.dispatch("kernel", "decode", stream=0):
                    _spin(50_000)
            # restored: this dispatch belongs to the outer window again
            with prof.dispatch("kernel", "decode", stream=0):
                _spin(50_000)
        prof.flush()
        paths = prof.write()
    profs = [p for k, p in sorted(paths.items()) if "trace" not in k]
    traces = [p for k, p in sorted(paths.items()) if "trace" in k]
    db = aggregate(profs, str(tmp_path / "db"), n_ranks=1, n_threads=1,
                   trace_paths=traces)
    lines = TraceDB(db.trace_db_path()).line_views()
    rows = {r[0]: r[1] for r in request_attribution(lines, db)}
    assert set(rows) == {"outer", "inner"}
    req, _ = window_labels(db)
    # no context carries both identities (replacement, not nesting)
    assert all(r in (None, "outer", "inner") for r in req)
