"""Guarded ``hypothesis`` import (degrade instead of erroring at collection).

``pytest.importorskip("hypothesis")`` at module scope would skip *whole*
modules, including their plain (non-property) tests.  Importing the names
from here instead keeps plain tests running everywhere: when hypothesis is
missing, ``given`` becomes a decorator that marks just the property tests
as skipped, and ``settings`` / ``st`` become inert stand-ins.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see pyproject [test])")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Anything:
        """Stand-in for ``strategies``: calls, attribute access, and
        decorator chains (``@st.composite``, ``.map``, ``.filter``) all
        return the same inert object — strategies are only built at
        decoration time and never drawn from once the test is
        skip-marked."""

        def __call__(self, *_a, **_k):
            return self

        def __getattr__(self, _name):
            return self

    st = _Anything()
