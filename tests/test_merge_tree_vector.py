"""Vectorized ``GlobalTree.merge_tree`` vs the sequential reference
loop (ISSUE 6 satellite): the two must produce **bitwise-equal trees**
— same frames, same parents, same children index, same mapping — on
any input, because the merge contract (and the canonical-database
bytes downstream) is defined by the reference semantics.
"""
import numpy as np

from repro.core.cct import Frame, HOST, PLACEHOLDER
from repro.core.pipeline.unify import GlobalTree


def random_tree(rng, n_nodes, n_keys=12):
    """A GlobalTree grown by random child insertions from a small frame
    pool (collisions force shared prefixes across trees)."""
    t = GlobalTree()
    ids = [0]
    for _ in range(n_nodes):
        parent = ids[int(rng.integers(len(ids)))]
        kind = HOST if rng.integers(2) else PLACEHOLDER
        f = Frame(kind, f"fn{rng.integers(n_keys)}",
                  f"mod{rng.integers(3)}", int(rng.integers(5)))
        ids.append(t.child(parent, f))
    return t


def clone_tree(src):
    """An independent GlobalTree with identical contents (fresh dicts,
    fresh lists) — so reference and vectorized merges cannot share
    state."""
    dst = GlobalTree()
    mapping = dst.merge_tree_reference(src)
    assert mapping.tolist() == list(range(len(src.frames)))
    return dst


def assert_trees_bitwise_equal(a, b):
    assert a.frames == b.frames
    assert list(a.parents) == list(b.parents)
    assert a._children == b._children


def test_vectorized_merge_tree_matches_reference_randomized():
    rng = np.random.default_rng(1234)
    for trial in range(25):
        base = random_tree(rng, int(rng.integers(1, 80)))
        other = random_tree(rng, int(rng.integers(1, 80)))
        ref, vec = clone_tree(base), clone_tree(base)
        m_ref = ref.merge_tree_reference(other)
        m_vec = vec.merge_tree(other)
        np.testing.assert_array_equal(m_ref, m_vec)
        assert_trees_bitwise_equal(ref, vec)


def test_vectorized_merge_chain_matches_reference():
    """A reduction over several trees (the unify fold shape): state must
    stay bitwise identical at every step, not just after one merge."""
    rng = np.random.default_rng(7)
    trees = [random_tree(rng, int(rng.integers(5, 60))) for _ in range(6)]
    ref, vec = clone_tree(trees[0]), clone_tree(trees[0])
    for t in trees[1:]:
        m_ref = ref.merge_tree_reference(t)
        m_vec = vec.merge_tree(t)
        np.testing.assert_array_equal(m_ref, m_vec)
        assert_trees_bitwise_equal(ref, vec)


def test_merge_tree_trivial_and_disjoint_cases():
    empty = GlobalTree()
    assert GlobalTree().merge_tree(empty).tolist() == [0]

    a, b = GlobalTree(), GlobalTree()
    ia = a.child(0, Frame(HOST, "left", "a.py", 1))
    b.child(0, Frame(HOST, "right", "b.py", 2))
    m = a.merge_tree(b)
    assert m.tolist() == [0, 2]           # appended after a's nodes
    assert len(a.frames) == 3
    # idempotent: merging b again is all hits
    assert a.merge_tree(b).tolist() == [0, 2]
    assert len(a.frames) == 3

    # a duck-typed shard-like object (frames list + parents ndarray)
    class Duck:
        frames = list(b.frames)
        parents = np.asarray(b.parents, np.int64)
    assert a.merge_tree(Duck()).tolist() == [0, 2]
