"""Meta-test: the skip inventory is frozen (ISSUE 3 test sweep).

Audit result (2026-07, re-audited for ISSUE 4): every skip in this
suite is *environment-dependent* — there is nothing to convert to a
running test or xfail:

- ``hypothesis_compat.py`` marks ``@given`` property tests skipped only
  when the optional ``hypothesis`` package is absent (they run in CI,
  which installs ``.[test]``).  ISSUE 4's merge-algebra properties
  (``test_merge_properties.py``) ride this same single guard — no new
  skip *mechanism* — and pin a no-hypothesis fallback by running the
  property bodies on a fixed example
  (``test_properties_hold_on_fixed_example``);
- ``test_structure.py`` skips one assertion block only on jax builds
  that emit no ``StackFrames`` metadata table;
- ``test_counters.py`` module-skips only when jax itself is absent
  (the analysis half of the suite stays importable without jax);
- ``test_goldens.py`` skips only under the explicit opt-in
  ``--update-goldens`` flag (the "test" then rewrites its golden; the
  ISSUE 4 merge-CLI golden reuses the same helper, so it adds no skip
  site either);
- ``test_derived_properties.py`` carries one ``skipif`` guard asserting
  the property suite is active whenever hypothesis is present.

This test freezes that inventory at the *source* level: any new
``skip`` / ``skipif`` / ``importorskip`` / ``xfail`` use anywhere in
``tests/`` fails here until it is added to the allowlist below with a
justification — so the skip count can never grow silently.
"""
import io
import os
import re
import tokenize

TESTS_DIR = os.path.dirname(__file__)

# (filename, mechanism) -> expected occurrence count, with why it is
# environment-dependent (or explicitly opted into).
ALLOWED_SKIPS = {
    ("hypothesis_compat.py", "pytest.mark.skip"): 1,   # hypothesis absent
    ("test_structure.py", "pytest.skip"): 1,           # no StackFrames table
    ("test_counters.py", "pytest.importorskip"): 1,    # jax absent
    ("test_kstruct.py", "pytest.importorskip"): 1,     # jax absent (the
    # structure-recovery half traces real Pallas kernels via make_jaxpr;
    # same guard as test_counters.py, no new mechanism)
    ("test_goldens.py", "pytest.skip"): 1,             # --update-goldens
    ("test_derived_properties.py", "pytest.mark.skipif"): 1,  # guard-guard
}

_MECHANISMS = (
    "pytest.importorskip",
    "pytest.mark.skipif",
    "pytest.mark.skip",
    "pytest.mark.xfail",
    "pytest.skip",
    "pytest.xfail",
)


def _code_text(path: str) -> str:
    """Source with string literals and comments dropped (tokenize-based),
    so docstrings that merely *mention* a mechanism never count."""
    out = []
    with open(path, "rb") as f:
        for tok in tokenize.tokenize(f.readline):
            if tok.type in (tokenize.STRING, tokenize.COMMENT):
                out.append(" ")
            elif tok.type == tokenize.NAME or tok.type == tokenize.OP:
                out.append(tok.string)
            else:
                out.append(" ")
    return " ".join(out)


def _scan():
    found = {}
    for fn in sorted(os.listdir(TESTS_DIR)):
        # this file only names mechanisms in strings/keys, but exclude it
        # anyway: it is the scanner, not a skip site
        if not fn.endswith(".py") or fn == os.path.basename(__file__):
            continue
        code = _code_text(os.path.join(TESTS_DIR, fn))
        for mech in _MECHANISMS:
            # any code-position reference counts — called OR a bare
            # ``@pytest.mark.skip`` decorator (valid pytest without
            # parens); the lookahead keeps the attribute name exact, so
            # ``pytest.mark.skip`` never also counts ``skipif`` sites
            pat = r"\s*\.\s*".join(re.escape(p) for p in mech.split(".")) \
                + r"(?![A-Za-z0-9_])"
            n = len(re.findall(pat, code))
            if n:
                found[(fn, mech)] = n
    return found


def test_skip_inventory_is_frozen():
    found = _scan()
    expected = dict(ALLOWED_SKIPS)
    assert found == expected, (
        "skip mechanisms changed.\n"
        f"  found:    {sorted(found.items())}\n"
        f"  expected: {sorted(expected.items())}\n"
        "New skips must be environment-dependent and added to "
        "ALLOWED_SKIPS in tests/test_meta_skips.py with a justification; "
        "environment-independent skips should be running tests or loud "
        "xfail(reason=...) instead.")


def test_meta_scanner_excludes_this_file():
    """The scanner must not trip on this file's own allowlist strings
    (they are never followed by an open paren)."""
    found = _scan()
    assert not any(fn == "test_meta_skips.py" for fn, _ in found)


def test_hypothesis_guard_is_the_only_hypothesis_import():
    """All property tests must go through hypothesis_compat so a missing
    hypothesis degrades to per-test skips, never collection errors."""
    offenders = []
    for fn in sorted(os.listdir(TESTS_DIR)):
        if not fn.endswith(".py") or fn == "hypothesis_compat.py":
            continue
        with open(os.path.join(TESTS_DIR, fn)) as f:
            for line in f:
                if re.match(r"\s*(from|import)\s+hypothesis\b", line):
                    offenders.append(fn)
    assert not offenders, \
        f"import hypothesis via tests/hypothesis_compat.py: {offenders}"
