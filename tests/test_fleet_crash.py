"""The fleet crash matrix (ISSUE 6): kill the daemon or the client at
**every labeled fault point**, restart, redeliver — and the database
must come out byte-identical to a one-shot ``aggregate()`` over the
union of acknowledged shards, with the on-disk database intact-or-
previous at every intermediate instant.

Three layers:

- deterministic matrix sweeps over ``DAEMON_FAULT_POINTS`` and
  ``CLIENT_FAULT_POINTS`` (in-process ``InjectedCrash``);
- a hypothesis property over random interleavings of deliveries,
  duplicates, crashes, and restarts;
- a subprocess soak (the CI chaos job: ``REPRO_FAULT_POINTS=all``)
  where the daemon CLI genuinely dies with ``os._exit`` and is
  relaunched.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.aggregate import aggregate
from repro.fleet import (DirectoryTransport, FleetDaemon, Journal,
                         ShardProducer)
from repro.fleet.client import CLIENT_FAULT_POINTS
from repro.fleet.daemon import DAEMON_FAULT_POINTS
from repro.ft import inject
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from test_fleet import build_fleet_inputs, build_shard, synth_shard_inputs
from test_merge import DB_FILES, assert_db_identical, db_bytes


# the chaos job's sweep-widening spec, captured at import: the autouse
# scrub below removes the variables from the environment so CLI
# subprocesses and in-process arm_from_env() calls never self-arm
_CHAOS_SPEC = os.environ.get(inject.ENV_POINTS, "")


@pytest.fixture(autouse=True)
def _scrub_inject_env(monkeypatch):
    monkeypatch.delenv(inject.ENV_POINTS, raising=False)
    monkeypatch.delenv(inject.ENV_MODE, raising=False)
    yield
    inject.clear()


def restart_daemon(tmp_path, **kw):
    """A fresh FleetDaemon over the same on-disk state — the restart
    path (the daemon holds no state that is not derivable from disk)."""
    return FleetDaemon(str(tmp_path / "fleet"), str(tmp_path / "spool"),
                       n_workers=1, **kw)


def restart_producer(tmp_path, daemon):
    return ShardProducer(str(tmp_path / "outbox"),
                         DirectoryTransport(daemon.incoming_dir),
                         producer="hostA", sleep=lambda s: None)


def db_intact(db_dir):
    """The database loads coherently (or does not exist yet) — the
    intact-or-previous guarantee, checked *at the instant of death*."""
    if not os.path.exists(os.path.join(db_dir, "meta.json")):
        return True
    from repro.core.merge import LoadedShard
    LoadedShard(db_dir)                      # raises on a torn database
    return True


# ---------------------------------------------------------------------------
# Registry sanity: the matrix really covers every labeled point
# ---------------------------------------------------------------------------
def test_fault_point_registry_covers_the_matrix():
    registered = set(inject.registered_points())
    assert set(DAEMON_FAULT_POINTS) <= registered
    assert set(CLIENT_FAULT_POINTS) <= registered
    # nothing registered escapes both sweeps
    assert registered == set(DAEMON_FAULT_POINTS) | set(CLIENT_FAULT_POINTS)


def test_inject_spec_parsing_and_env():
    assert inject.parse_spec("a,b:3") == {"a": 1, "b": 3}
    assert inject.parse_spec(" a , b:2 ,") == {"a": 1, "b": 2}
    with pytest.raises(ValueError, match=">= 1"):
        inject.parse_spec("a:0")
    plan = inject.parse_spec("all")
    assert plan == {lb: 1 for lb in inject.registered_points()}
    assert not inject.arm_from_env({})
    assert inject.arm_from_env({inject.ENV_POINTS: "x.y:2"})
    assert inject.armed() == {"x.y": 2}
    inject.clear()
    with pytest.raises(ValueError, match="raise|exit"):
        inject.arm("a", mode="bogus")


def test_fault_point_counts_down_and_is_uncatchable():
    inject.arm("p:2")
    inject.fault_point("p")                  # first hit: count down
    with pytest.raises(inject.InjectedCrash):
        inject.fault_point("p")
    inject.clear()
    with inject.injected("q"):
        with pytest.raises(BaseException) as ei:
            try:
                inject.fault_point("q")
            except Exception:                # quarantine-style handler...
                pytest.fail("InjectedCrash must not be catchable "
                            "as Exception")
        assert ei.value.label == "q"
    inject.fault_point("q")                  # disarmed: no-op


# ---------------------------------------------------------------------------
# Daemon crash matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("point", DAEMON_FAULT_POINTS)
def test_daemon_crash_matrix(tmp_path, point):
    shard_dbs, ref = build_fleet_inputs(tmp_path, n_shards=2)
    # late shard delivered after the fleet db already exists
    late_db, late_paths, late_traces = build_shard(tmp_path, 7)
    daemon = restart_daemon(tmp_path)
    producer = restart_producer(tmp_path, daemon)
    for db in shard_dbs:
        producer.stage(db)
    producer.deliver()
    daemon.poll_once()

    producer.stage(late_db)
    producer.deliver()
    with inject.injected(point):
        with pytest.raises(inject.InjectedCrash):
            daemon.poll_once()
    assert db_intact(daemon.db_dir)          # intact-or-previous, now

    daemon2 = restart_daemon(tmp_path)       # restart + replay
    daemon2.poll_once()
    want = str(tmp_path / "want_all")
    paths, traces = [], []
    for i in range(2):
        p, t = synth_shard_inputs(tmp_path / f"w{i}", 100 + i, 10 * i)
        paths += p
        traces += t
    aggregate(paths + late_paths, want, trace_paths=traces + late_traces)
    assert_db_identical(daemon2.db_dir, want)
    journal = Journal.load(daemon2.db_dir)
    assert len(journal.applied) == 3
    # a second restart poll is a pure no-op
    before = db_bytes(daemon2.db_dir)
    restart_daemon(tmp_path).poll_once()
    assert db_bytes(str(tmp_path / "fleet")) == before


@pytest.mark.parametrize("point", DAEMON_FAULT_POINTS)
def test_daemon_crash_then_duplicate_redelivery(tmp_path, point):
    """Crash + the producer re-sending everything it ever staged must
    not double-fold anything."""
    shard_dbs, ref = build_fleet_inputs(tmp_path, n_shards=2)
    daemon = restart_daemon(tmp_path)
    producer = restart_producer(tmp_path, daemon)
    producer.stage(shard_dbs[0])             # first fold lands cleanly,
    producer.deliver()                       # so every point (incl. the
    daemon.poll_once()                       # swap) is reachable below
    producer.stage(shard_dbs[1])
    producer.deliver()
    with inject.injected(point):
        with pytest.raises(inject.InjectedCrash):
            daemon.poll_once()
    # paranoid producer: restage + redeliver the full history
    producer2 = restart_producer(tmp_path, daemon)
    for db in shard_dbs:
        producer2.stage(db)
    producer2.deliver()
    daemon2 = restart_daemon(tmp_path)
    daemon2.poll_once()
    daemon2.poll_once()
    assert_db_identical(daemon2.db_dir, ref)
    assert len(Journal.load(daemon2.db_dir).applied) == 2


# ---------------------------------------------------------------------------
# Client crash matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("point", CLIENT_FAULT_POINTS)
def test_client_crash_matrix(tmp_path, point):
    shard_dbs, ref = build_fleet_inputs(tmp_path, n_shards=2)
    daemon = restart_daemon(tmp_path)
    producer = restart_producer(tmp_path, daemon)
    with inject.injected(point):
        with pytest.raises(inject.InjectedCrash):
            for db in shard_dbs:
                producer.stage(db)
            producer.deliver()
    # client restart: sweep temps, restage everything, redeliver
    producer2 = restart_producer(tmp_path, daemon)
    for db in shard_dbs:
        producer2.stage(db)
    rep = producer2.deliver()
    assert not rep.failed
    daemon.poll_once()
    assert_db_identical(daemon.db_dir, ref)
    assert len(Journal.load(daemon.db_dir).applied) == 2
    # no temp droppings survive in outbox or incoming
    leftovers = [fn for d in (producer2.outbox_dir, daemon.incoming_dir)
                 for fn in os.listdir(d) if fn.startswith(".tmp-")]
    assert leftovers == []


# ---------------------------------------------------------------------------
# Property: any interleaving == one-shot aggregation
# ---------------------------------------------------------------------------
N_SHARDS = 2
OPS = (["poll"]
       + [("deliver", i) for i in range(N_SHARDS)]
       + [("crash", p) for p in DAEMON_FAULT_POINTS])


@settings(max_examples=6, deadline=None)
@given(st.lists(st.sampled_from(OPS), min_size=1, max_size=8))
def test_random_interleavings_converge_to_one_shot(tmp_path_factory,
                                                   schedule):
    tmp_path = tmp_path_factory.mktemp("interleave")
    shard_dbs, ref = build_fleet_inputs(tmp_path, n_shards=N_SHARDS)
    daemon = restart_daemon(tmp_path)
    producer = restart_producer(tmp_path, daemon)
    for op in schedule:
        if op == "poll":
            daemon.poll_once()
        elif op[0] == "deliver":             # includes re-deliveries
            producer.stage(shard_dbs[op[1]])
            producer.deliver()
        else:
            with inject.injected(op[1]):
                try:
                    daemon.poll_once()
                except inject.InjectedCrash:
                    pass
            daemon = restart_daemon(tmp_path)
            producer = restart_producer(tmp_path, daemon)
    # quiesce: deliver everything once more, then a clean poll
    for db in shard_dbs:
        producer.stage(db)
    producer.deliver()
    daemon = restart_daemon(tmp_path)
    daemon.poll_once()
    assert_db_identical(daemon.db_dir, ref)
    assert len(Journal.load(daemon.db_dir).applied) == N_SHARDS


# ---------------------------------------------------------------------------
# Subprocess soak: genuine process death (the CI chaos job)
# ---------------------------------------------------------------------------
def _run_fleet_cli(args, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env.update(env_extra or {})
    return subprocess.run(
        [sys.executable, "-m", "repro.fleet", *args],
        capture_output=True, text=True, env=env, timeout=180)


def test_soak_daemon_process_death_at_every_point(tmp_path):
    """Relaunch loop over real ``os._exit`` deaths.  Locally sweeps a
    fast subset; the CI chaos job sets ``REPRO_FAULT_POINTS=all`` to
    sweep every registered daemon point."""
    points = list(DAEMON_FAULT_POINTS) if _CHAOS_SPEC == inject.ALL else [
        "daemon.admit.post_unpack", "merge.commit.mid_swap",
        "daemon.fold.post_commit"]
    shard_dbs, ref = build_fleet_inputs(tmp_path, n_shards=2)
    db = str(tmp_path / "fleet")
    spool = str(tmp_path / "spool")
    incoming = os.path.join(spool, "incoming")
    os.makedirs(incoming, exist_ok=True)
    send = _run_fleet_cli(["send", *shard_dbs,
                           "--outbox", str(tmp_path / "outbox"),
                           "--to", incoming])
    assert send.returncode == 0, send.stderr
    daemon_args = ["daemon", db, "--spool", spool, "--interval", "0",
                   "--max-polls", "1", "--workers", "1"]
    for point in points:
        r = _run_fleet_cli(daemon_args, {
            inject.ENV_POINTS: point, inject.ENV_MODE: "exit"})
        # the point may sit on an already-completed path (e.g. admit
        # points after everything was admitted): death or clean exit
        assert r.returncode in (inject.EXIT_CODE, 0), \
            (point, r.returncode, r.stderr)
        if r.returncode == inject.EXIT_CODE:
            assert f"os._exit({inject.EXIT_CODE})" in r.stderr
    final = _run_fleet_cli(daemon_args)
    assert final.returncode == 0, final.stderr
    assert_db_identical(db, ref)
    assert len(Journal.load(db).applied) == 2
