"""Kernel-interior attribution (ISSUE 8 tentpole; repro.core.kstruct).

Covers the whole thread: structure recovery from the real Pallas
kernels (jaxpr trace -> loops / inlined scopes / source lines), the
sample descent (two-level draw, governor cap preserved exactly), the
profiler splice (interior frames under the kernel's GPU_OP context),
both ``top_hot_loops`` views, the counter-collector refinement, and the
canonical-database byte contract (one-shot aggregate == shards +
merge_databases with interiors attributed).

Plus the ISSUE 8 sampling satellite: the deterministic ``pc_samples``
path must never return an empty list for a non-empty module, even at
the governor floor (cap=1) over a many-op module with spread weights.
"""
import os

import numpy as np
import pytest

from repro.core import sampling
from repro.core.aggregate import aggregate
from repro.core.cct import Frame, GPU_FUNC, GPU_LOOP, GPU_OP
from repro.core.kstruct import KernelLeaf, KernelStructure
from repro.core.merge import merge_databases
from repro.core.profiler import Profiler
from repro.core.structure import parse_hlo
from test_merge import assert_db_identical


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------
KERNEL_HLO = """HloModule kmod

ENTRY %main (p0: f32[256,256]) -> f32[256,256] {
  %p0 = f32[256,256] parameter(0)
  %fa = f32[256,256] custom-call(%p0), custom_call_target="tpu_custom_call", metadata={op_name="jit(step)/flash_attention"}
  %mul = f32[256,256] multiply(%fa, %fa), metadata={op_name="jit(step)/scale"}
  ROOT %out = f32[256,256] add(%mul, %p0)
}
"""


def hand_structure(name="flash_attention", file="flash.py"):
    """A small deterministic interior: one grid loop, two scopes,
    weighted leaves — jax-independent, so goldens/determinism tests do
    not depend on jaxpr spelling across jax versions."""
    loop = Frame(GPU_LOOP, "grid:kv_blocks", file, 36)
    blk = Frame(GPU_FUNC, "_block", file, 63)
    init = Frame(GPU_FUNC, "_init", file, 44)
    return KernelStructure(name, file, 36, [
        KernelLeaf((loop, blk, Frame(GPU_OP, "dot_general", file, 67)),
                   weight=6e-6, stall="compute", flops=2.1e9, bytes=0.0),
        KernelLeaf((loop, blk, Frame(GPU_OP, "exp", file, 80)),
                   weight=1e-6, stall="compute", flops=1.8e8, bytes=0.0),
        KernelLeaf((loop, init, Frame(GPU_OP, "swap", file, 47)),
                   weight=1.5e-6, stall="memory", flops=0.0, bytes=3.3e7),
    ])


def bound_module():
    mod = parse_hlo(KERNEL_HLO)
    assert mod.bind_kernel_structure(hand_structure()) == 1
    return mod


# ---------------------------------------------------------------------------
# sample descent (distribute)
# ---------------------------------------------------------------------------
def test_distribute_exact_total_and_deterministic():
    ks = hand_structure()
    for count in (1, 2, 7, 100, 12345):
        pairs = ks.distribute(count)
        assert sum(c for _, c in pairs) == count    # cap survives exactly
        assert pairs == ks.distribute(count)        # pure function
        assert all(c > 0 for _, c in pairs)
    assert ks.distribute(0) == []


def test_distribute_rng_total_preserved():
    ks = hand_structure()
    rng = np.random.default_rng(3)
    for count in (1, 9, 400):
        assert sum(c for _, c in ks.distribute(count, rng)) == count


def test_distribute_single_sample_goes_to_heaviest_leaf():
    ks = hand_structure()
    [(leaf, c)] = ks.distribute(1)
    assert c == 1
    assert leaf == int(np.argmax([lf.weight for lf in ks.leaves]))


def test_distribute_many_equal_leaves_exact():
    """Largest-remainder apportionment: equal weights, count not a
    multiple of the leaf count — floor+0.5 rounding would overshoot or
    undershoot; apportionment hits the total exactly."""
    file = "k.py"
    leaves = [KernelLeaf((Frame(GPU_OP, f"op{i}", file, i),),
                         weight=1.0, stall="compute") for i in range(7)]
    ks = KernelStructure("k", file, 1, leaves)
    for count in (1, 3, 7, 10, 20):
        assert sum(c for _, c in ks.distribute(count)) == count


# ---------------------------------------------------------------------------
# satellite: deterministic pc_samples never empty (governor floor)
# ---------------------------------------------------------------------------
def test_pc_samples_cap1_never_empty_many_ops():
    """Regression (ISSUE 8): with cap=1 and weights spread over many ops
    (every p < 0.5), np.floor(n*p + 0.5) rounded every count to zero and
    pc_samples returned [] — fine-grained attribution silently died at
    the governor's floor rung."""
    lines = ["HloModule many", "",
             "ENTRY %main (p0: f32[64,64]) -> f32[64,64] {",
             "  %p0 = f32[64,64] parameter(0)"]
    prev = "p0"
    for i in range(40):
        lines.append(f"  %op{i} = f32[64,64] multiply(%{prev}, %p0)")
        prev = f"op{i}"
    lines += [f"  ROOT %out = f32[64,64] add(%{prev}, %p0)", "}"]
    mod = parse_hlo("\n".join(lines))
    w, _ = sampling.op_weights(mod)
    p = w / w.sum()
    assert p.max() < 0.5                       # the failing regime
    samples = sampling.pc_samples(mod, 1.0, rate_hz=1e6, cap=1)
    assert samples, "deterministic pc_samples returned [] at cap=1"
    assert sum(s.count for s in samples) == 1
    # the fallback attributes the sample to the heaviest op
    assert samples[0].op_index == int(np.argmax(w))


def test_pc_samples_cap_respected_with_bound_kernel():
    mod = bound_module()
    for cap in (1, 5, 64):
        samples = sampling.pc_samples(mod, 1.0, rate_hz=1e6, cap=cap)
        assert samples
        assert sum(s.count for s in samples) <= cap


# ---------------------------------------------------------------------------
# binding + two-level draw
# ---------------------------------------------------------------------------
def test_bind_matches_custom_call_by_op_name():
    mod = parse_hlo(KERNEL_HLO)
    assert mod.bind_kernel_structure(hand_structure()) == 1
    (idx, ks), = mod.kernel_structures().items()
    assert mod.all_ops()[idx].opcode == "custom-call"
    assert ks.name == "flash_attention"
    # no match -> no binding
    assert mod.bind_kernel_structure(
        hand_structure(name="nonexistent_kernel")) == 0


def test_bound_custom_call_gains_interior_weight():
    plain = parse_hlo(KERNEL_HLO)
    wp, _ = sampling.op_weights(plain)
    mod = bound_module()
    wb, _ = sampling.op_weights(mod)
    ccall = next(op.index for op in mod.all_ops()
                 if op.opcode == "custom-call")
    # interior roofline model raises the op's modeled time above the
    # opaque custom-call heuristic
    assert wb[ccall] > wp[ccall] > 0.0


def test_two_level_draw_descends_into_leaves():
    mod = bound_module()
    samples = sampling.pc_samples(mod, 1e-3, rate_hz=1e6)
    ccall = next(op.index for op in mod.all_ops()
                 if op.opcode == "custom-call")
    interior = [s for s in samples if s.op_index == ccall]
    assert interior and all(s.leaf >= 0 for s in interior)
    assert {s.leaf for s in interior} <= {0, 1, 2}
    ks = mod.kernel_structures()[ccall]
    for s in interior:
        assert s.stall == ks.leaves[s.leaf].stall
    # non-bound ops stay leafless
    assert all(s.leaf == -1 for s in samples if s.op_index != ccall)


# ---------------------------------------------------------------------------
# recovery from the real Pallas kernels
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def recovered():
    pytest.importorskip("jax")     # recovery traces real Pallas kernels
    from repro.kernels import kernel_structures
    return {ks.name: ks for ks in kernel_structures()}


def test_recovers_all_three_kernels(recovered):
    assert set(recovered) == {"flash_attention", "decode_attention",
                              "ssm_scan"}
    for ks in recovered.values():
        assert len(ks.leaves) >= 10
        assert ks.active_s > 0 and ks.total_flops > 0


def test_flash_attention_interior_shape(recovered):
    ks = recovered["flash_attention"]
    assert ks.file == "flash_attention.py"
    kinds = {f.kind for lf in ks.leaves for f in lf.frames}
    assert kinds == {GPU_LOOP, GPU_FUNC, GPU_OP}
    # the sequential grid axis is the kernel's outer loop
    assert all(lf.frames[0].name == "grid:kv_blocks" for lf in ks.leaves)
    # pl.when bodies appear as inlined scopes with call-site lines
    scopes = {f.name for lf in ks.leaves for f in lf.frames
              if f.kind == GPU_FUNC}
    assert {"_init", "_block", "_finish"} <= scopes
    # the MXU matmuls are recovered as compute-bound dot_general leaves
    dots = [lf for lf in ks.leaves if lf.frames[-1].name == "dot_general"]
    assert len(dots) >= 2
    assert all(lf.stall == "compute" and lf.flops > 0 for lf in dots)
    # scratch init traffic is memory-bound
    init = [lf for lf in ks.leaves
            if any(f.name == "_init" for f in lf.frames)]
    assert init and all(lf.stall == "memory" for lf in init)
    # leaves carry real source lines of the kernel file
    assert all(lf.line > 0 for lf in ks.leaves)


def test_decode_and_ssm_interiors(recovered):
    dec = recovered["decode_attention"]
    assert all(lf.frames[0].name == "grid:kv_blocks" for lf in dec.leaves)
    ssm = recovered["ssm_scan"]
    assert all(lf.frames[0].name == "grid:chunks" for lf in ssm.leaves)
    # ssd kernel: three MXU matmuls per chunk
    dots = [lf for lf in ssm.leaves
            if lf.frames[-1].name == "dot_general"]
    assert len(dots) >= 3


def test_recovery_is_deterministic(recovered):
    from repro.kernels import flash_attention
    a = flash_attention.kernel_structure()
    b = flash_attention.kernel_structure()
    assert [lf.frames for lf in a.leaves] == [lf.frames for lf in b.leaves]
    assert [lf.weight for lf in a.leaves] == [lf.weight for lf in b.leaves]


# ---------------------------------------------------------------------------
# profiler splice + views
# ---------------------------------------------------------------------------
def run_rank(out_dir, rank=0):
    prof = Profiler(str(out_dir), tracing=True, unwind=False, rank=rank)
    mid = prof.register_module("step", KERNEL_HLO)
    prof.register_kernel_structures(mid, [hand_structure()])
    with prof:
        for _ in range(4):
            with prof.dispatch("kernel", "step", stream=0, module_id=mid,
                               duration_ns=1_000_000):
                pass
        prof.flush()
        paths = prof.write()
    profs = [p for k, p in sorted(paths.items()) if "trace" not in k]
    traces = [p for k, p in sorted(paths.items()) if "trace" in k]
    return profs, traces


def test_interior_frames_under_kernel_op(tmp_path):
    profs, traces = run_rank(tmp_path / "m0")
    db = aggregate(profs, str(tmp_path / "db"), trace_paths=traces)
    roots = [g for g, f in enumerate(db.frames)
             if f.kind == GPU_FUNC and db.parents[g] >= 0
             and db.frames[int(db.parents[g])].kind == GPU_OP]
    assert roots, "no kernel-interior root (GPU_FUNC under GPU_OP)"
    assert {db.frames[g].name for g in roots} == {"flash_attention"}
    # interior leaves carry gpu_inst samples
    samp = db.stats["sum"][:, db.metric_id("gpu_inst/samples")]
    assert samp[roots[0]] > 0        # inclusive: the whole descent
    names = {db.frames[g].name for g in range(len(db.frames))}
    assert {"grid:kv_blocks", "_block", "dot_general"} <= names


def test_viewer_top_hot_loops(tmp_path):
    from repro.core import viewer
    profs, traces = run_rank(tmp_path / "m0")
    db = aggregate(profs, str(tmp_path / "db"), trace_paths=traces)
    out = viewer.top_hot_loops(db)
    assert "flash_attention" in out
    assert "grid:kv_blocks" in out
    assert "flash.py:67" in out and "dot_general" in out
    # stall breakdown columns are present
    assert "compute" in out and "memory" in out
    # a database without gpu_inst degrades gracefully
    from test_goldens import fixture_db as _  # noqa: F401 (idiom check)
    out2 = viewer.top_hot_loops(db, top=1)
    assert len(out2.splitlines()) == 3       # header + colnames + 1 row


def test_traceview_top_hot_loops_joins_busy_ns(tmp_path):
    from repro.traceview.stats import top_hot_loops
    from repro.traceview.tracedb import TraceDB
    profs, traces = run_rank(tmp_path / "m0")
    db = aggregate(profs, str(tmp_path / "db"), trace_paths=traces)
    lines = TraceDB(db.trace_db_path()).line_views()
    rows = top_hot_loops(lines, db)
    assert rows
    kernels = {r[0] for r in rows}
    assert kernels == {"flash_attention"}
    # sample counts positive and busy estimate prorated from the
    # enclosing placeholder's windowed busy time
    assert all(r[4] > 0 for r in rows)
    assert sum(r[5] for r in rows) > 0
    # rows sorted by samples descending
    assert [r[4] for r in rows] == sorted((r[4] for r in rows),
                                          reverse=True)


def test_interior_byte_determinism_shards_vs_oneshot(tmp_path):
    """ISSUE 8 acceptance: a 2-rank kernel-interior-attributed one-shot
    aggregate() is byte-identical to per-rank shards + merge_databases
    (interior frames are ordinary tree paths; the canonical-database
    contract holds unchanged)."""
    runs = [run_rank(tmp_path / f"m{r}", rank=r) for r in range(2)]
    profs = [p for ps, _ in runs for p in ps]
    traces = [t for _, ts in runs for t in ts]
    one = str(tmp_path / "one")
    aggregate(profs, one, trace_paths=traces)
    shards = []
    for i, (ps, ts) in enumerate(runs):
        d = str(tmp_path / f"shard{i}")
        aggregate(ps, d, trace_paths=ts)
        shards.append(d)
    merged = str(tmp_path / "merged")
    merge_databases(shards, merged)
    assert_db_identical(merged, one)


# ---------------------------------------------------------------------------
# counter-collector refinement
# ---------------------------------------------------------------------------
def test_static_counters_refined_by_bound_structure():
    from repro.counters.collector import static_counters
    from repro.counters.taxonomy import COUNTER_INDEX
    plain = static_counters(parse_hlo(KERNEL_HLO)).copy()
    bound = static_counters(bound_module()).copy()
    i_fl, i_mxu = COUNTER_INDEX["flops"], COUNTER_INDEX["mxu_flops"]
    i_inst = COUNTER_INDEX["inst_executed"]
    ks = hand_structure()
    assert bound[i_fl] == pytest.approx(plain[i_fl] + ks.total_flops)
    assert bound[i_mxu] == pytest.approx(plain[i_mxu] + 2.1e9)
    assert bound[i_inst] == pytest.approx(plain[i_inst] + len(ks.leaves))
    assert bound[COUNTER_INDEX["active_ns"]] > plain[
        COUNTER_INDEX["active_ns"]]


def test_binding_invalidates_module_caches():
    mod = parse_hlo(KERNEL_HLO)
    from repro.counters.collector import static_counters
    w0, _ = sampling.op_weights(mod)
    c0 = static_counters(mod).copy()
    mod.bind_kernel_structure(hand_structure())
    w1, _ = sampling.op_weights(mod)
    c1 = static_counters(mod)
    assert w1.sum() > w0.sum()
    assert c1.sum() > c0.sum()


def test_real_kernels_end_to_end_in_viewer(tmp_path, recovered):
    """ISSUE 8 acceptance: PC samples inside flash_attention,
    decode_attention, and ssm_scan attribute to named interior contexts
    visible in viewer top-down and traceview top_hot_loops."""
    from repro.core import viewer
    from repro.traceview.stats import top_hot_loops
    from repro.traceview.tracedb import TraceDB
    names = ("flash_attention", "decode_attention", "ssm_scan")
    lines_hlo = ["HloModule step", "",
                 "ENTRY %main (p0: f32[256,256]) -> f32[256,256] {",
                 "  %p0 = f32[256,256] parameter(0)"]
    prev = "p0"
    for i, n in enumerate(names):
        lines_hlo.append(
            f'  %k{i} = f32[256,256] custom-call(%{prev}), '
            f'custom_call_target="tpu_custom_call", '
            f'metadata={{op_name="jit(step)/{n}"}}')
        prev = f"k{i}"
    lines_hlo += [f"  ROOT %out = f32[256,256] add(%{prev}, %p0)", "}"]
    prof = Profiler(str(tmp_path / "m"), tracing=True, unwind=False)
    mid = prof.register_module("step", "\n".join(lines_hlo))
    assert prof.register_kernel_structures(
        mid, [recovered[n] for n in names]) == 3
    with prof:
        for _ in range(4):
            with prof.dispatch("kernel", "step", stream=0, module_id=mid,
                               duration_ns=1_000_000):
                pass
        prof.flush()
        paths = prof.write()
    profs = [p for k, p in sorted(paths.items()) if "trace" not in k]
    traces = [p for k, p in sorted(paths.items()) if "trace" in k]
    db = aggregate(profs, str(tmp_path / "db"), trace_paths=traces)
    td = viewer.top_down(db, "gpu_inst/samples", max_depth=30)
    for n in names:
        assert n in td, f"{n} interior missing from viewer top-down"
    # GPU_LOOP frames render as "loop at <file>:<line>" in top-down
    assert "loop at flash_attention.py" in td
    assert "loop at ssm_scan.py" in td
    rows = top_hot_loops(TraceDB(db.trace_db_path()).line_views(), db,
                         k=100)
    assert {r[0] for r in rows} == set(names)
    # rows point at real kernel source files and lines
    assert any(r[2].startswith("flash_attention.py:") for r in rows)
