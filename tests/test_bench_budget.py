"""benchmarks/run.py budget enforcement (ISSUE 3 satellite) and
baseline comparison (ISSUE 5 satellite): a tracked benchmark exceeding
its stated budget — or, under ``--compare``, regressing >25% against
its committed BENCH_*.json baseline — must fail the sweep loudly,
naming the benchmark and stage, not just write BENCH_*.json."""
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.run import (ALL, COMPARE_TOLERANCE, TRACKED,  # noqa: E402
                            baseline_regressions, budget_regressions,
                            load_baseline)


def test_budget_regression_messages_name_bench_and_stage():
    results = {"merge_under_budget": False, "merge_budget_s": 8.0,
               "merge_s": 9.1, "schedule_under_budget": True,
               "schedules_per_s": 1e5}
    msgs = budget_regressions("counters", results)
    assert len(msgs) == 1
    assert "counters" in msgs[0] and "merge" in msgs[0]
    assert "merge_budget_s" in msgs[0]


def test_no_regressions_when_under_budget():
    assert budget_regressions("x", {"a_under_budget": True, "b": 1}) == []
    assert budget_regressions("x", {}) == []


def test_multiple_stages_reported_independently():
    msgs = budget_regressions("traceview", {
        "raster_under_budget": False, "raster_budget_s": 1.0,
        "merge_under_budget": False, "merge_budget_s": 2.0})
    assert len(msgs) == 2
    stages = {m.split(": ")[1].split(" ")[0] for m in msgs}
    assert stages == {"raster", "merge"}


def test_counters_benchmark_is_tracked():
    assert "counters" in ALL and "counters" in TRACKED


def test_merge_benchmark_is_tracked_with_budget():
    """ISSUE 4: bench_merge rides the sweep (and --small smoke in CI),
    persists BENCH_merge.json, and enforces its merge-stage budget —
    since ISSUE 9 a calibration-probe ratio, not absolute seconds."""
    from benchmarks import bench_merge
    assert "merge" in ALL and "merge" in TRACKED
    assert bench_merge.MERGE_BUDGET_X > 0
    msgs = budget_regressions("merge", {
        "merge_under_budget": False,
        "merge_budget_x": bench_merge.MERGE_BUDGET_X})
    assert len(msgs) == 1 and "merge" in msgs[0]


def test_traceview_zoompan_budget_is_probe_ratio():
    """ISSUE 9: the traceview gates are calibration-probe ratios, and
    the pyramid's interactive bar is a >=10x speedup over the per-event
    re-scan at full size."""
    from benchmarks import bench_traceview
    assert "traceview" in ALL and "traceview" in TRACKED
    assert bench_traceview.ZOOMPAN_BUDGET_MIN_X >= 10.0
    assert bench_traceview.RASTER_BUDGET_X > 0
    assert bench_traceview.PYRAMID_QUERY_BUDGET_X > 0
    msgs = budget_regressions("traceview", {
        "zoompan_under_budget": False,
        "zoompan_budget_min_x": bench_traceview.ZOOMPAN_BUDGET_MIN_X})
    assert len(msgs) == 1 and "zoompan" in msgs[0]


# ---------------------------------------------------------------------------
# ISSUE 5: bench_pipeline tracking + --compare baseline regression gate
# ---------------------------------------------------------------------------
def test_pipeline_benchmark_is_tracked_with_speedup_budget():
    from benchmarks import bench_pipeline
    assert "pipeline" in ALL and "pipeline" in TRACKED
    assert bench_pipeline.SPEEDUP_BUDGET_MIN_X >= 1.8
    msgs = budget_regressions("pipeline", {
        "speedup_under_budget": False,
        "speedup_budget_min_x": bench_pipeline.SPEEDUP_BUDGET_MIN_X})
    assert len(msgs) == 1 and "pipeline" in msgs[0] and "speedup" in msgs[0]


def test_committed_pipeline_baseline_exists():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base = load_baseline(repo, "pipeline")
    assert base.get("bench") == "pipeline"
    r = base["results"]
    assert r["byte_identical"] is True
    # the speedup bar needs parallel hardware; a baseline recorded on a
    # single-core box carries the explicit waiver instead
    assert r.get("speedup_budget_waived_single_core") \
        or r["speedup_4w_x"] >= 1.8


def test_baseline_regression_over_tolerance_fails():
    base = {"small": False,
            "results": {"merge_s": 1.0, "one_shot_s": 4.0}}
    new = {"merge_s": 1.0 * (1 + COMPARE_TOLERANCE) + 0.01,
           "one_shot_s": 4.0}
    msgs = baseline_regressions("merge", new, base, small=False)
    assert len(msgs) == 1
    assert "merge" in msgs[0] and "merge_s regressed" in msgs[0]
    assert "1.000s" in msgs[0]


def test_baseline_within_tolerance_passes():
    base = {"small": False, "results": {"raster_s": 1.0}}
    assert baseline_regressions(
        "traceview", {"raster_s": 1.2}, base, small=False) == []


def test_baseline_skips_constants_and_nonmeasurements():
    """Budget bounds and pinned seed numbers are constants — raising a
    budget must never read as a perf regression; speedups (_x) are
    higher-better and not stage times."""
    base = {"small": False,
            "results": {"merge_budget_s": 2.0, "seed_merge_s": 0.3,
                        "speedup_4w_x": 3.0, "merge_s": 1.0}}
    new = {"merge_budget_s": 99.0, "seed_merge_s": 99.0,
           "speedup_4w_x": 1.0, "merge_s": 1.0}
    assert baseline_regressions("merge", new, base, small=False) == []


def test_baseline_size_mismatch_and_missing_are_skipped():
    base = {"small": False, "results": {"merge_s": 0.1}}
    assert baseline_regressions("merge", {"merge_s": 9.9}, base,
                                small=True) == []
    assert baseline_regressions("merge", {"merge_s": 9.9}, {},
                                small=False) == []


def test_load_baseline_roundtrip(tmp_path):
    path = tmp_path / "BENCH_x.json"
    path.write_text(json.dumps({"bench": "x", "small": False,
                                "results": {"a_s": 1.0}}))
    assert load_baseline(str(tmp_path), "x")["results"]["a_s"] == 1.0
    assert load_baseline(str(tmp_path), "missing") == {}


# ---------------------------------------------------------------------------
# ISSUE 8: calibration-normalized --compare + bench_kstruct tracking
# ---------------------------------------------------------------------------
def test_kstruct_benchmark_is_tracked_with_descent_budget():
    from benchmarks import bench_kstruct
    assert "kstruct" in ALL and "kstruct" in TRACKED
    assert bench_kstruct.DESCENT_OVERHEAD_BUDGET_X > 1.0
    msgs = budget_regressions("kstruct", {
        "descent_under_budget": False,
        "descent_budget_max_x": bench_kstruct.DESCENT_OVERHEAD_BUDGET_X})
    assert len(msgs) == 1 and "kstruct" in msgs[0] and "descent" in msgs[0]


def test_calibration_probe_is_deterministic_workload():
    from benchmarks.run import calibration_probe
    t = calibration_probe(repeats=1)
    assert 0 < t < 30.0


def test_calibrated_compare_cancels_uniform_machine_noise():
    """Regression (ISSUE 8): the old absolute gate flagged a uniformly
    2x-slower CI host as a perf regression.  With probes recorded on
    both sides, a uniform slowdown inflates stage and probe alike — the
    normalized ratio is unchanged and the gate stays quiet."""
    base = {"small": False, "calibration_s": 0.10,
            "results": {"merge_s": 1.0, "fold_s": 0.5}}
    new = {"merge_s": 2.0, "fold_s": 1.0}        # everything 2x slower...
    assert baseline_regressions("merge", new, base, small=False,
                                calibration=0.20) == []   # ...probe too


def test_calibrated_compare_flags_genuine_stage_regression():
    """A stage regressing *relative to the probe* still trips the gate,
    and the message carries both ratios and both raw sides."""
    base = {"small": False, "calibration_s": 0.10,
            "results": {"merge_s": 1.0, "fold_s": 0.5}}
    new = {"merge_s": 4.0, "fold_s": 1.0}        # merge 2x vs calibration
    msgs = baseline_regressions("merge", new, base, small=False,
                                calibration=0.20)
    assert len(msgs) == 1
    assert "merge_s regressed" in msgs[0] and "calibration" in msgs[0]
    assert "10.00x" in msgs[0] and "20.00x" in msgs[0]
    assert "probe" in msgs[0]


def test_compare_falls_back_to_absolute_without_probe():
    """Baselines recorded before the probe existed (no calibration_s)
    keep the absolute-seconds gate."""
    base = {"small": False, "results": {"merge_s": 1.0}}
    msgs = baseline_regressions("merge", {"merge_s": 2.0}, base,
                                small=False, calibration=0.20)
    assert len(msgs) == 1 and "1.000s -> 2.000s" in msgs[0]
    # and symmetrically: probe on the baseline but not this run
    base2 = {"small": False, "calibration_s": 0.1,
             "results": {"merge_s": 1.0}}
    msgs2 = baseline_regressions("merge", {"merge_s": 2.0}, base2,
                                 small=False)
    assert len(msgs2) == 1 and "calibration" not in msgs2[0]


def test_committed_baselines_carry_calibration_probe():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for name in TRACKED:
        base = load_baseline(repo, name)
        assert base.get("bench") == name, f"missing BENCH_{name}.json"
        assert base.get("calibration_s", 0) > 0, \
            f"BENCH_{name}.json lacks a calibration probe"


def test_compare_skips_throughput_per_s_keys():
    """``*_per_s`` is a throughput (higher is better) — the ``_s``
    suffix gate must not read a throughput *improvement* as a time
    regression."""
    base = {"small": False, "calibration_s": 0.1,
            "results": {"dispatches_per_s": 1e4, "merge_s": 1.0}}
    new = {"dispatches_per_s": 2e4, "merge_s": 1.0}
    assert baseline_regressions("kstruct", new, base, small=False,
                                calibration=0.1) == []
