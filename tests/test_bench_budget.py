"""benchmarks/run.py budget enforcement (ISSUE 3 satellite): a tracked
benchmark exceeding its stated budget must fail the sweep loudly, naming
the benchmark and stage — not just write BENCH_*.json."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.run import ALL, TRACKED, budget_regressions  # noqa: E402


def test_budget_regression_messages_name_bench_and_stage():
    results = {"merge_under_budget": False, "merge_budget_s": 8.0,
               "merge_s": 9.1, "schedule_under_budget": True,
               "schedules_per_s": 1e5}
    msgs = budget_regressions("counters", results)
    assert len(msgs) == 1
    assert "counters" in msgs[0] and "merge" in msgs[0]
    assert "merge_budget_s" in msgs[0]


def test_no_regressions_when_under_budget():
    assert budget_regressions("x", {"a_under_budget": True, "b": 1}) == []
    assert budget_regressions("x", {}) == []


def test_multiple_stages_reported_independently():
    msgs = budget_regressions("traceview", {
        "raster_under_budget": False, "raster_budget_s": 1.0,
        "merge_under_budget": False, "merge_budget_s": 2.0})
    assert len(msgs) == 2
    stages = {m.split(": ")[1].split(" ")[0] for m in msgs}
    assert stages == {"raster", "merge"}


def test_counters_benchmark_is_tracked():
    assert "counters" in ALL and "counters" in TRACKED


def test_merge_benchmark_is_tracked_with_budget():
    """ISSUE 4: bench_merge rides the sweep (and --small smoke in CI),
    persists BENCH_merge.json, and enforces its merge-stage budget."""
    from benchmarks import bench_merge
    assert "merge" in ALL and "merge" in TRACKED
    assert bench_merge.MERGE_BUDGET_S > 0
    msgs = budget_regressions("merge", {
        "merge_under_budget": False,
        "merge_budget_s": bench_merge.MERGE_BUDGET_S})
    assert len(msgs) == 1 and "merge" in msgs[0]
