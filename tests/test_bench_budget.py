"""benchmarks/run.py budget enforcement (ISSUE 3 satellite) and
baseline comparison (ISSUE 5 satellite): a tracked benchmark exceeding
its stated budget — or, under ``--compare``, regressing >25% against
its committed BENCH_*.json baseline — must fail the sweep loudly,
naming the benchmark and stage, not just write BENCH_*.json."""
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.run import (ALL, COMPARE_TOLERANCE, TRACKED,  # noqa: E402
                            baseline_regressions, budget_regressions,
                            load_baseline)


def test_budget_regression_messages_name_bench_and_stage():
    results = {"merge_under_budget": False, "merge_budget_s": 8.0,
               "merge_s": 9.1, "schedule_under_budget": True,
               "schedules_per_s": 1e5}
    msgs = budget_regressions("counters", results)
    assert len(msgs) == 1
    assert "counters" in msgs[0] and "merge" in msgs[0]
    assert "merge_budget_s" in msgs[0]


def test_no_regressions_when_under_budget():
    assert budget_regressions("x", {"a_under_budget": True, "b": 1}) == []
    assert budget_regressions("x", {}) == []


def test_multiple_stages_reported_independently():
    msgs = budget_regressions("traceview", {
        "raster_under_budget": False, "raster_budget_s": 1.0,
        "merge_under_budget": False, "merge_budget_s": 2.0})
    assert len(msgs) == 2
    stages = {m.split(": ")[1].split(" ")[0] for m in msgs}
    assert stages == {"raster", "merge"}


def test_counters_benchmark_is_tracked():
    assert "counters" in ALL and "counters" in TRACKED


def test_merge_benchmark_is_tracked_with_budget():
    """ISSUE 4: bench_merge rides the sweep (and --small smoke in CI),
    persists BENCH_merge.json, and enforces its merge-stage budget."""
    from benchmarks import bench_merge
    assert "merge" in ALL and "merge" in TRACKED
    assert bench_merge.MERGE_BUDGET_S > 0
    msgs = budget_regressions("merge", {
        "merge_under_budget": False,
        "merge_budget_s": bench_merge.MERGE_BUDGET_S})
    assert len(msgs) == 1 and "merge" in msgs[0]


# ---------------------------------------------------------------------------
# ISSUE 5: bench_pipeline tracking + --compare baseline regression gate
# ---------------------------------------------------------------------------
def test_pipeline_benchmark_is_tracked_with_speedup_budget():
    from benchmarks import bench_pipeline
    assert "pipeline" in ALL and "pipeline" in TRACKED
    assert bench_pipeline.SPEEDUP_BUDGET_MIN_X >= 1.8
    msgs = budget_regressions("pipeline", {
        "speedup_under_budget": False,
        "speedup_budget_min_x": bench_pipeline.SPEEDUP_BUDGET_MIN_X})
    assert len(msgs) == 1 and "pipeline" in msgs[0] and "speedup" in msgs[0]


def test_committed_pipeline_baseline_exists():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base = load_baseline(repo, "pipeline")
    assert base.get("bench") == "pipeline"
    assert base["results"]["byte_identical"] is True
    assert base["results"]["speedup_4w_x"] >= 1.8


def test_baseline_regression_over_tolerance_fails():
    base = {"small": False,
            "results": {"merge_s": 1.0, "one_shot_s": 4.0}}
    new = {"merge_s": 1.0 * (1 + COMPARE_TOLERANCE) + 0.01,
           "one_shot_s": 4.0}
    msgs = baseline_regressions("merge", new, base, small=False)
    assert len(msgs) == 1
    assert "merge" in msgs[0] and "merge_s regressed" in msgs[0]
    assert "1.000s" in msgs[0]


def test_baseline_within_tolerance_passes():
    base = {"small": False, "results": {"raster_s": 1.0}}
    assert baseline_regressions(
        "traceview", {"raster_s": 1.2}, base, small=False) == []


def test_baseline_skips_constants_and_nonmeasurements():
    """Budget bounds and pinned seed numbers are constants — raising a
    budget must never read as a perf regression; speedups (_x) are
    higher-better and not stage times."""
    base = {"small": False,
            "results": {"merge_budget_s": 2.0, "seed_merge_s": 0.3,
                        "speedup_4w_x": 3.0, "merge_s": 1.0}}
    new = {"merge_budget_s": 99.0, "seed_merge_s": 99.0,
           "speedup_4w_x": 1.0, "merge_s": 1.0}
    assert baseline_regressions("merge", new, base, small=False) == []


def test_baseline_size_mismatch_and_missing_are_skipped():
    base = {"small": False, "results": {"merge_s": 0.1}}
    assert baseline_regressions("merge", {"merge_s": 9.9}, base,
                                small=True) == []
    assert baseline_regressions("merge", {"merge_s": 9.9}, {},
                                small=False) == []


def test_load_baseline_roundtrip(tmp_path):
    path = tmp_path / "BENCH_x.json"
    path.write_text(json.dumps({"bench": "x", "small": False,
                                "results": {"a_s": 1.0}}))
    assert load_baseline(str(tmp_path), "x")["results"]["a_s"] == 1.0
    assert load_baseline(str(tmp_path), "missing") == {}
