"""Incremental & sharded database merge (ISSUE 4 tentpole).

The acceptance contract: ``merge_databases`` over *any* sharding of a
measurement directory produces a database — tree, stats, cms, pms,
trace.db — byte-identical to a one-shot ``aggregate()`` over the union.
This file pins that with fixed shardings (including shards built with
*different* ``n_ranks``), in-place incremental extension, CLI golden
output, and the error paths; tests/test_merge_properties.py adds the
randomized merge-algebra properties on top.
"""
import json
import os

import numpy as np
import pytest

from repro.core.aggregate import Database, aggregate, canonical_order
from repro.core.cct import Frame
from repro.core.merge import LoadedShard, main as merge_main, \
    merge_databases, summarize
from repro.core.sparse import read_pms
from test_aggregate_equiv import synth_inputs
from test_goldens import check_golden

DB_FILES = ("stats.npz", "metrics.cms", "metrics.pms", "trace.db")
META_KEYS = ("frames", "parents", "metrics", "profiles", "cms", "pms")


def db_bytes(out_dir, files=DB_FILES):
    out = {}
    for fn in files:
        p = os.path.join(out_dir, fn)
        out[fn] = open(p, "rb").read() if os.path.exists(p) else None
    return out


def meta_of(out_dir):
    with open(os.path.join(out_dir, "meta.json")) as f:
        meta = json.load(f)
    return {k: meta[k] for k in META_KEYS}


def assert_db_identical(got_dir, want_dir):
    got, want = db_bytes(got_dir), db_bytes(want_dir)
    for fn in DB_FILES:
        assert got[fn] == want[fn], f"{fn} diverged"
    assert meta_of(got_dir) == meta_of(want_dir)


def traces_of(paths):
    return [p.replace(".rpro", ".rtrc") for p in paths]


def build_shards(tmp_path, paths, split, **kw):
    """Aggregate each shard of ``split`` into its own database dir."""
    dirs = []
    for i, sp in enumerate(split):
        d = str(tmp_path / f"shard{i}")
        traces = [t for t in traces_of(sp) if os.path.exists(t)]
        aggregate(sp, d, trace_paths=traces,
                  **{"n_ranks": i + 1, "n_threads": 2, **kw})
        dirs.append(d)
    return dirs


# --------------------------------------------------------------------------
# The pinned multi-shard round trip (acceptance criterion)
# --------------------------------------------------------------------------
def test_shard_then_merge_is_byte_identical_to_one_shot(tmp_path):
    paths, traces = synth_inputs(tmp_path, seed=40, n_profiles=7)
    one = str(tmp_path / "one")
    aggregate(paths, one, trace_paths=traces)
    # interleaved 3-way sharding; every shard aggregated with a DIFFERENT
    # n_ranks (the canonical contract makes that irrelevant)
    dirs = build_shards(tmp_path, paths,
                        [paths[0::3], paths[1::3], paths[2::3]])
    merged = str(tmp_path / "merged")
    merge_databases(dirs, merged)
    assert_db_identical(merged, one)


def test_merge_is_shard_order_invariant(tmp_path):
    paths, _ = synth_inputs(tmp_path, seed=41, n_profiles=6)
    dirs = build_shards(tmp_path, paths, [paths[:2], paths[2:4], paths[4:]])
    a = str(tmp_path / "a")
    b = str(tmp_path / "b")
    merge_databases(dirs, a)
    merge_databases(list(reversed(dirs)), b)
    assert db_bytes(a) == db_bytes(b)
    assert meta_of(a) == meta_of(b)


def test_merge_is_associative(tmp_path):
    paths, _ = synth_inputs(tmp_path, seed=42, n_profiles=6)
    dirs = build_shards(tmp_path, paths, [paths[:2], paths[2:4], paths[4:]])
    ab = str(tmp_path / "ab")
    merge_databases(dirs[:2], ab)
    nested = str(tmp_path / "nested")
    merge_databases([ab, dirs[2]], nested)
    flat = str(tmp_path / "flat")
    merge_databases(dirs, flat)
    assert db_bytes(nested) == db_bytes(flat)
    assert meta_of(nested) == meta_of(flat)


def test_merge_single_db_is_idempotent(tmp_path):
    paths, traces = synth_inputs(tmp_path, seed=43, n_profiles=3)
    one = str(tmp_path / "one")
    aggregate(paths, one, trace_paths=traces)
    again = str(tmp_path / "again")
    merge_databases([one], again)
    assert_db_identical(again, one)


def test_aggregate_is_canonical_across_configs(tmp_path):
    """The contract merge stands on: one-shot bytes are a pure function
    of the profile set — n_ranks/n_threads/path order all irrelevant."""
    paths, traces = synth_inputs(tmp_path, seed=44, n_profiles=6)
    a = str(tmp_path / "a")
    aggregate(paths, a, n_ranks=1, n_threads=1, trace_paths=traces)
    b = str(tmp_path / "b")
    aggregate(list(reversed(paths)), b, n_ranks=4, n_threads=4,
              trace_paths=list(reversed(traces)))
    assert db_bytes(a) == db_bytes(b)
    assert meta_of(a) == meta_of(b)


def test_unmapped_traces_compose_byte_identically(tmp_path):
    """A trace with no matching profile passes through aggregate() with
    raw ctx ids and a ``ctx_unmapped`` identity flag; merge must copy
    such lines verbatim (remapping ids that were never database ctx ids
    would diverge from the one-shot)."""
    from repro.core.trace import TraceWriter
    from repro.traceview.tracedb import TraceDB
    paths, traces = synth_inputs(tmp_path, seed=52, n_profiles=4)
    for i in range(2):   # orphan traces, one per shard
        tw = TraceWriter(str(tmp_path / f"orphan{i}.rtrc"),
                         {"rank": 10 + i, "stream": 0, "type": "gpu"})
        tw.append(0, 50, 12345)      # not a database ctx id
        tw.close()
        traces.append(tw.path)
    one = str(tmp_path / "one")
    aggregate(paths, one, trace_paths=traces)
    split = [paths[:2], paths[2:]]
    dirs = []
    for i, sp in enumerate(split):
        d = str(tmp_path / f"shard{i}")
        aggregate(sp, d, trace_paths=traces_of(sp)
                  + [str(tmp_path / f"orphan{i}.rtrc")])
        dirs.append(d)
    merged = str(tmp_path / "merged")
    merge_databases(dirs, merged)
    assert_db_identical(merged, one)
    tdb = TraceDB(os.path.join(merged, "trace.db"))
    flagged = [ln for ln in tdb.lines if ln.identity.get("ctx_unmapped")]
    assert len(flagged) == 2
    # raw ids preserved verbatim
    i = tdb.lines.index(flagged[0])
    assert list(tdb.ctx(i)) == [12345]


# --------------------------------------------------------------------------
# Incremental mode
# --------------------------------------------------------------------------
def test_incremental_aggregate_extends_in_place(tmp_path):
    paths, traces = synth_inputs(tmp_path, seed=45, n_profiles=6)
    one = str(tmp_path / "one")
    aggregate(paths, one, trace_paths=traces)
    inc = str(tmp_path / "inc")
    aggregate(paths[:4], inc, trace_paths=traces_of(paths[:4]))
    timing = {}
    db = aggregate(paths[4:], inc, base_db=inc,
                   trace_paths=traces_of(paths[4:]), timing=timing)
    assert_db_identical(inc, one)
    assert len(db.profile_ids) == 6
    assert "incremental_s" in timing


def test_incremental_respects_trace_db_flag(tmp_path):
    """trace_db=False must flow through the incremental path: no fresh
    trace.db is built, and a stale one (pre-merge ctx ids) is removed
    rather than left behind."""
    paths, traces = synth_inputs(tmp_path, seed=53, n_profiles=4)
    inc = str(tmp_path / "inc")
    aggregate(paths[:2], inc, trace_paths=traces_of(paths[:2]))
    assert os.path.exists(os.path.join(inc, "trace.db"))
    aggregate(paths[2:], inc, base_db=inc,
              trace_paths=traces_of(paths[2:]), trace_db=False)
    assert not os.path.exists(os.path.join(inc, "trace.db"))


def test_in_place_merge_leaves_no_staging_droppings(tmp_path):
    """In-place extension stages outputs in a sibling temp dir and swaps
    them in with per-file renames; nothing extra may remain."""
    paths, traces = synth_inputs(tmp_path, seed=54, n_profiles=4)
    inc = str(tmp_path / "inc")
    aggregate(paths[:2], inc, trace_paths=traces_of(paths[:2]))
    before = set(os.listdir(tmp_path))
    merged_again = merge_databases(
        [inc, build_shards(tmp_path, paths, [paths[2:]])[0]], inc)
    assert len(merged_again.profile_ids) == 4
    after = set(os.listdir(tmp_path))
    assert not any(n.startswith(".merge_staging_") for n in after)
    assert after - before == {"shard0"}


def test_incremental_aggregate_into_fresh_dir(tmp_path):
    paths, traces = synth_inputs(tmp_path, seed=46, n_profiles=4)
    one = str(tmp_path / "one")
    aggregate(paths, one, trace_paths=traces)
    base = str(tmp_path / "base")
    aggregate(paths[:2], base, trace_paths=traces_of(paths[:2]))
    out = str(tmp_path / "extended")
    aggregate(paths[2:], out, base_db=Database.load(base),
              trace_paths=traces_of(paths[2:]))
    assert_db_identical(out, one)
    # the base is untouched
    assert len(Database.load(base).profile_ids) == 2


# --------------------------------------------------------------------------
# PMS/CMS reader round trips on fresh and merged databases
# --------------------------------------------------------------------------
def test_pms_reader_roundtrips_merged_database(tmp_path):
    from repro.core.sparse import read_cms, write_pms
    paths, _ = synth_inputs(tmp_path, seed=47, n_profiles=5,
                            with_traces=False)
    dirs = build_shards(tmp_path, paths, [paths[:2], paths[2:]])
    merged = str(tmp_path / "merged")
    db = merge_databases(dirs, merged)
    pvals = read_pms(db.pms_path())
    assert [pv.profile_id for pv in pvals] == list(range(5))
    # write-back of what the reader returned is byte-identical
    back = str(tmp_path / "back.pms")
    write_pms(back, pvals, n_workers=1)
    assert open(back, "rb").read() == \
        open(db.pms_path(), "rb").read()
    # and the CMS view of the same cube carries identical triplets
    cvals = {pv.profile_id: pv for pv in read_cms(db.cms_path())}
    for pv in pvals:
        cv = cvals[pv.profile_id]
        assert np.array_equal(pv.ctx, cv.ctx)
        assert np.array_equal(pv.metric, cv.metric)
        assert np.array_equal(pv.values, cv.values)


# --------------------------------------------------------------------------
# Errors and edges
# --------------------------------------------------------------------------
def test_merge_requires_inputs():
    with pytest.raises(ValueError, match="at least one"):
        merge_databases([], "nowhere")


def test_merge_rejects_mismatched_metrics(tmp_path):
    from repro.core.cct import CCT, Frame, HOST
    from repro.core.metrics import MetricRegistry
    from repro.core.profmt import write_profile
    paths, _ = synth_inputs(tmp_path, seed=48, n_profiles=2,
                            with_traces=False)
    a = str(tmp_path / "a")
    aggregate(paths[:1], a)
    reg = MetricRegistry()
    reg.register_kind("weird", ("only",))
    cct = CCT()
    cct.insert_path([Frame(HOST, "f", "x.py", 1)]).metrics.add(
        reg.kind("weird"), "only", 1.0)
    p = str(tmp_path / "weird.rpro")
    write_profile(p, cct, reg, {"rank": 9}, [])
    b = str(tmp_path / "b")
    aggregate([p], b)
    with pytest.raises(ValueError, match="metric columns"):
        merge_databases([a, b], str(tmp_path / "out"))


def test_merge_with_empty_database(tmp_path):
    paths, _ = synth_inputs(tmp_path, seed=49, n_profiles=2,
                            with_traces=False)
    a = str(tmp_path / "a")
    aggregate(paths, a)
    e = str(tmp_path / "empty")
    aggregate([], e)
    out = str(tmp_path / "out")
    db = merge_databases([e, a], out)
    assert len(db.profile_ids) == 2
    assert db.metrics == Database.load(a).metrics
    both_empty = merge_databases([e, e], str(tmp_path / "out2"))
    assert len(both_empty.frames) == 1 and both_empty.metrics == []


def test_merge_duplicate_profiles_accumulate_as_multiset(tmp_path):
    """Merging a database with itself doubles every profile (documented
    multiset semantics) — sums double, count doubles, min/max hold."""
    paths, _ = synth_inputs(tmp_path, seed=50, n_profiles=2,
                            with_traces=False)
    a = str(tmp_path / "a")
    db_a = aggregate(paths, a)
    out = str(tmp_path / "out")
    db = merge_databases([a, a], out)
    assert len(db.profile_ids) == 4
    assert np.array_equal(db.stats["sum"], 2 * db_a.stats["sum"])
    assert np.array_equal(db.stats["count"], 2 * db_a.stats["count"])
    assert np.array_equal(db.stats["min"], db_a.stats["min"])
    assert np.array_equal(db.stats["max"], db_a.stats["max"])


def test_merge_refuses_to_replace_non_database_dir(tmp_path):
    """The commit step swaps whole directories; a typo'd -o pointing at
    unrelated files must error out, not vaporize them."""
    paths, _ = synth_inputs(tmp_path, seed=55, n_profiles=2,
                            with_traces=False)
    a = str(tmp_path / "a")
    aggregate(paths, a)
    victim = tmp_path / "victim"
    victim.mkdir()
    (victim / "precious.txt").write_text("keep me")
    with pytest.raises(ValueError, match="not a database directory"):
        merge_databases([a], str(victim))
    assert (victim / "precious.txt").read_text() == "keep me"
    assert not any(n.startswith(".merge_staging_")
                   for n in os.listdir(tmp_path))


def test_loaded_shard_rejects_torn_database(tmp_path):
    paths, _ = synth_inputs(tmp_path, seed=51, n_profiles=2,
                            with_traces=False)
    a = str(tmp_path / "a")
    aggregate(paths, a)
    meta_path = os.path.join(a, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["profiles"]["99"] = {"rank": 99}
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="torn"):
        LoadedShard(a)


def test_canonical_order_properties():
    """Topological + child-order-by-frame-key, on a hand-built tree."""
    frames = [Frame("root", "<program root>"),
              Frame("host", "z", "b.py", 1),   # inserted before "a"
              Frame("host", "a", "a.py", 1),
              Frame("host", "k", "c.py", 2)]   # child of z
    parents = np.array([-1, 0, 0, 1])
    new_id = canonical_order(frames, parents)
    # "a" sorts before "z" at level 1; "k" fills level 2
    assert list(new_id) == [0, 2, 1, 3]


# --------------------------------------------------------------------------
# CLI (+ golden summary output)
# --------------------------------------------------------------------------
@pytest.fixture()
def cli_shards(tmp_path):
    """Fully deterministic shards for the CLI golden (fixed identities,
    fixed values — no RNG)."""
    from repro.core.cct import CCT, Frame, HOST, PLACEHOLDER
    from repro.core.metrics import default_registry
    from repro.core.profmt import write_profile
    from repro.core.trace import TraceWriter
    reg = default_registry()
    paths = []
    for r in range(4):
        cct = CCT()
        main_n = cct.insert_path([Frame(HOST, "main", "app.py", 1)])
        ph = cct.get_or_insert(main_n,
                               Frame(PLACEHOLDER, "kernel:train", "0", 0))
        ph.metrics.add(reg.kind("gpu_kernel"), "invocations", r + 1.0)
        ph.metrics.add(reg.kind("gpu_kernel"), "time_ns", 100.0 * (r + 1))
        p = str(tmp_path / f"profile_r{r}_t0.rpro")
        write_profile(p, cct, reg,
                      {"rank": r, "thread": 0, "type": "cpu"}, [])
        tw = TraceWriter(p.replace(".rpro", ".rtrc"),
                         {"rank": r, "thread": 0, "type": "cpu"})
        tw.append(0, 100, main_n.node_id)
        tw.append(100, 200, ph.node_id)
        tw.close()
        paths.append(p)
    dirs = []
    for i in range(2):
        sp = paths[2 * i:2 * i + 2]
        d = str(tmp_path / f"shard_{i}")
        aggregate(sp, d, trace_paths=traces_of(sp))
        dirs.append(d)
    return dirs


def test_merge_cli_summary_golden(cli_shards, tmp_path, capsys,
                                  update_goldens):
    out = str(tmp_path / "merged_db")
    rc = merge_main([*cli_shards, "-o", out])
    assert rc == 0
    text = capsys.readouterr().out.rstrip("\n")
    check_golden("merge_cli_summary.txt", text, update_goldens)
    assert os.path.isdir(out)


def test_merge_cli_no_trace_db(cli_shards, tmp_path, capsys):
    out = str(tmp_path / "merged_db")
    rc = merge_main([*cli_shards, "-o", out, "--no-trace-db",
                     "--workers", "1"])
    assert rc == 0
    assert not os.path.exists(os.path.join(out, "trace.db"))
    assert "trace.db: (none)" in capsys.readouterr().out


def test_summarize_counts_match_database(cli_shards, tmp_path):
    out = str(tmp_path / "merged_db")
    db = merge_databases(cli_shards, out)
    text = summarize(db, cli_shards)
    assert f"profiles: {len(db.profile_ids)}" in text
    assert f"contexts: {len(db.frames)}" in text
    nnz = sum(len(pv.values) for pv in read_pms(db.pms_path()))
    assert f"nnz:      {nnz}" in text
