"""GPipe pipeline parallelism over a stage axis (subprocess: needs >1
device for a real stage axis; in-process test uses a 1-stage mesh)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.pipeline import bubble_fraction, pipeline_apply


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(4, 28) < 0.1


def test_single_stage_identity_mesh():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("stage",))
    w = jnp.full((1, 4, 4), 2.0)          # one stage, a 4x4 weight

    def layer(p, x):
        return x @ p

    x = jnp.ones((3, 2, 4))               # M=3 microbatches of (2, 4)
    with mesh:
        out = pipeline_apply(layer, w, x, mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w[0]),
                               rtol=1e-6)


PIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply

from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("stage",))
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (4, 8, 8)) * 0.3   # 4 stages

def layer(p, x):
    return jnp.tanh(x @ p)

M = 6
x = jax.random.normal(jax.random.PRNGKey(1), (M, 2, 8))
with mesh:
    out = pipeline_apply(layer, W, x, mesh=mesh)

# reference: sequential application of all four stages
want = x
for s in range(4):
    want = jnp.tanh(want @ W[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                           atol=1e-5)
print("PIPELINE_OK")
"""


def test_four_stage_pipeline_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", PIPE_SCRIPT], capture_output=True,
        text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300)
    assert "PIPELINE_OK" in proc.stdout, proc.stderr[-2000:]
