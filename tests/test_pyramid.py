"""Trace tile pyramid (ISSUE 9): build determinism, the exactness
contract (tile-backed queries bitwise-equal to per-event answers),
filter composition, cache staleness, reader lifecycle — plus the
window-correctness regression sweep that rode along (unsorted-line
default windows, filter edge clipping, vectorized request spans)."""
import os

import numpy as np
import pytest

from repro.core.cct import (GPU_FUNC, GPU_LOOP, GPU_OP, HOST, PLACEHOLDER,
                            Frame, tree_depths)
from repro.core.trace import TraceData
from repro.traceview import (TraceDB, TraceFilter, TracePyramid,
                             apply_filter, build_db, build_pyramid,
                             ensure_pyramid, pyramid_path_for, rasterize,
                             stats, summary)
from repro.traceview.pyramid import _db_header_sha

from tests.test_traceview import SynthDB


# ---------------------------------------------------------------------------
# fixture: 4 lines x 500 events, random tree, out-of-range ctx included
# ---------------------------------------------------------------------------
N_CTX = 50


def _synth_lines(rng, n_lines=4, n_events=500):
    srcs = []
    for r in range(n_lines):
        ss, ee, cc = [], [], []
        t = 1000 + r * 17
        for _ in range(n_events):
            t += int(rng.integers(0, 300))
            d = int(rng.integers(1, 500))
            ss.append(t)
            ee.append(t + d)
            # includes out-of-range ctx: attributes to root like the
            # per-event paths
            cc.append(int(rng.integers(-7, N_CTX + 3)))
            if rng.random() < 0.7:       # else: overlapping/nested events
                t += d
        srcs.append(TraceData({"rank": r, "thread": 0, "type": "cpu"},
                              np.asarray(ss, np.int64),
                              np.asarray(ee, np.int64),
                              np.asarray(cc, np.int64)))
    return srcs


@pytest.fixture(scope="module")
def pyrdb(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("pyr")
    rng = np.random.default_rng(42)
    parents = np.full(N_CTX, -1, np.int64)
    frames = [Frame("root", "<program root>")]
    for i in range(1, N_CTX):
        parents[i] = rng.integers(0, i)
        frames.append(Frame(HOST, f"fn{i}", "app.py", i))
    db = build_db(_synth_lines(rng), str(tmp / "trace.db"))
    pyr = build_pyramid(db.path, parents)
    yield SynthDB(frames, parents), db, pyr
    pyr.close()
    db.close()


def _windows(db, pyr):
    t_min, t_max = db.time_range()
    span = t_max - t_min
    return [(t_min, t_max),                       # full
            (t_min, t_min + 1),                   # 1 ns
            (t_min + 137, t_max - 451),           # unaligned
            (t_min + span // 3, t_min + span // 3 + 7919),
            (t_min - 5000, t_max + 5000),         # beyond the data
            (t_max + 10, t_max + 20),             # fully outside
            (t_min + 64, t_min + 64 + pyr.bin_ns * 3 + 11)]


# ---------------------------------------------------------------------------
# determinism: trace.pyr bytes are a pure function of (trace.db, parents)
# ---------------------------------------------------------------------------
def test_pyramid_rebuild_deterministic(tmp_path, pyrdb):
    sdb, db, pyr = pyrdb
    again = build_pyramid(db.path, sdb.parents, str(tmp_path / "again.pyr"))
    assert open(pyr.path, "rb").read() == open(again.path, "rb").read()
    assert pyr.source["db_header_sha256"] == _db_header_sha(db.path)
    assert pyr.source["n_events"] == db.n_events
    again.close()


# ---------------------------------------------------------------------------
# exactness contract: tiles answer bitwise-equal to the per-event scans
# ---------------------------------------------------------------------------
def test_interval_profile_bitwise_equal(pyrdb):
    sdb, db, pyr = pyrdb
    lines = db.line_views()
    for a, b in _windows(db, pyr):
        ref = stats.interval_profile(lines, N_CTX, a, b)
        got = pyr.interval_profile(N_CTX, a, b)
        np.testing.assert_array_equal(ref, got, err_msg=f"[{a},{b})")


def test_occupancy_bitwise_equal(pyrdb):
    sdb, db, pyr = pyrdb
    lines = db.line_views()
    for a, b in _windows(db, pyr):
        if b <= a:
            continue
        for nbins in (1, 7, 64):
            ref = stats.occupancy(lines, a, b, nbins)
            got = pyr.occupancy(a, b, nbins)
            np.testing.assert_array_equal(ref, got,
                                          err_msg=f"[{a},{b}) x{nbins}")
    # the stats entry point delegates, with line selection
    a, b = db.time_range()
    np.testing.assert_array_equal(
        stats.occupancy(lines, a, b, 8, pyramid=pyr, line_ids=[1, 3]),
        stats.occupancy([lines[1], lines[3]], a, b, 8))


def test_summary_tile_backed_equal(pyrdb):
    sdb, db, pyr = pyrdb
    lines = db.line_views()
    for depth in (1, 3):
        assert summary(lines, sdb, depth=depth, top=10**9) \
            == summary(None, sdb, depth=depth, top=10**9, pyramid=pyr)
    a, b = db.time_range()
    assert summary(lines, sdb, t0=a + 101, t1=b - 57, depth=2) \
        == summary(None, sdb, t0=a + 101, t1=b - 57, depth=2, pyramid=pyr)


def test_exact_raster_pixel_equal(pyrdb):
    sdb, db, pyr = pyrdb
    lines = db.line_views()
    for depth in (0, 2, 5):
        for a, b in _windows(db, pyr)[:5]:
            ref = rasterize(lines, sdb.parents, t0=a, t1=b, width=97,
                            height=16, depth=depth)
            got = pyr.rasterize(sdb.parents, t0=a, t1=b, width=97,
                                height=16, depth=depth, mode="exact")
            np.testing.assert_array_equal(ref.pixels, got.pixels,
                                          err_msg=f"d{depth} [{a},{b})")
    # default window (no t0/t1) matches too
    ref = rasterize(lines, sdb.parents, width=97, height=16, depth=2)
    got = pyr.rasterize(sdb.parents, width=97, height=16, depth=2,
                        mode="exact")
    np.testing.assert_array_equal(ref.pixels, got.pixels)


def test_dominant_raster_reads_tiles(pyrdb):
    sdb, db, pyr = pyrdb
    # a window aligned to level-2 tiles, one pixel per tile: the raster
    # must be exactly the stored dominant-context row
    lev = 2
    w_lev = pyr.bin_ns << lev
    nb = pyr.lines[0].levels[lev]["bins"]
    r = pyr.rasterize(sdb.parents, t0=pyr.t_min, t1=pyr.t_min + nb * w_lev,
                      width=nb, height=len(pyr), depth=1, mode="dominant")
    for row, i in enumerate(r.line_ids):
        np.testing.assert_array_equal(r.pixels[row],
                                      pyr.dominant_tiles(int(i), lev, 1))
    # auto mode: zoomed past the finest bin -> exact -> per-event pixels
    a = pyr.t_min + 100
    b = a + max(pyr.bin_ns // 2, 1) * 8
    got = pyr.rasterize(sdb.parents, t0=a, t1=b, width=8, height=4,
                        depth=2, mode="auto")
    ref = rasterize(db.line_views(), sdb.parents, t0=a, t1=b, width=8,
                    height=4, depth=2)
    np.testing.assert_array_equal(got.pixels, ref.pixels)


def test_filter_composes_with_tiles(pyrdb):
    sdb, db, pyr = pyrdb
    lines = db.line_views()
    t_min, t_max = db.time_range()
    flt = TraceFilter(ranks={1, 2}, t0=t_min + 100, t1=t_max - 100,
                      subtree=3)
    line_ids, ctx_mask, f0, f1 = pyr.select(flt, sdb.parents)
    assert line_ids == [1, 2] and (f0, f1) == (flt.t0, flt.t1)
    kept = apply_filter(lines, flt, sdb.parents)
    np.testing.assert_array_equal(
        stats.interval_profile(kept, N_CTX, f0, f1),
        pyr.interval_profile(N_CTX, f0, f1, lines=line_ids,
                             ctx_mask=ctx_mask))
    # and through the summary entry point (flt composes at tile level)
    assert summary(kept, sdb, t0=f0, t1=f1, depth=2, top=10**9) \
        == summary(None, sdb, depth=2, top=10**9, pyramid=pyr, flt=flt)


# ---------------------------------------------------------------------------
# ensure_pyramid: lazy cache + staleness on either input
# ---------------------------------------------------------------------------
def test_ensure_pyramid_cache_and_staleness(tmp_path):
    rng = np.random.default_rng(3)
    parents = np.array([-1, 0, 1], np.int64)
    srcs = _synth_lines(rng, n_lines=2, n_events=40)
    db = build_db(srcs, str(tmp_path / "trace.db"))
    pyr_path = pyramid_path_for(db.path)

    pyr = ensure_pyramid(db.path, parents)       # builds
    assert pyr.path == pyr_path and os.path.exists(pyr_path)
    pyr.close()
    stamp = os.stat(pyr_path).st_mtime_ns
    ensure_pyramid(db.path, parents).close()     # cache hit: no rebuild
    assert os.stat(pyr_path).st_mtime_ns == stamp

    # parents changed -> stale -> rebuilt
    parents2 = np.array([-1, 0, 0], np.int64)
    pyr2 = ensure_pyramid(db.path, parents2)
    assert pyr2.parents_sha256 != TracePyramid(pyr_path).parents_sha256 \
        or os.stat(pyr_path).st_mtime_ns != stamp
    pyr2.close()

    # trace.db changed (re-merged with an extra line) -> stale -> rebuilt
    db.close()
    extra = TraceData({"rank": 9, "thread": 0, "type": "cpu"},
                      np.array([5], np.int64), np.array([9], np.int64),
                      np.array([1], np.int64))
    with build_db([db.path, extra], db.path) as db2:
        with ensure_pyramid(db2.path, parents2) as pyr3:
            assert len(pyr3) == len(db2) == 3
            assert pyr3.source["db_header_sha256"] == _db_header_sha(db2.path)


# ---------------------------------------------------------------------------
# lifecycle: close() semantics on both readers (ISSUE 9 satellite)
# ---------------------------------------------------------------------------
def test_pyramid_close_semantics(tmp_path):
    rng = np.random.default_rng(5)
    parents = np.array([-1, 0], np.int64)
    db = build_db(_synth_lines(rng, n_lines=1, n_events=30),
                  str(tmp_path / "trace.db"))
    a, b = db.time_range()
    with build_pyramid(db.path, parents) as pyr:
        pyr.interval_profile(2, a, b)                # opens its own tdb
    with pytest.raises(ValueError):
        pyr.busy_tiles(0, 0)
    with pytest.raises(ValueError):
        pyr.interval_profile(2, a, b)
    db.close()
    with pytest.raises(ValueError):
        db.starts(0)
    with pytest.raises(ValueError):
        db.raw()


def test_tracedb_remerge_in_place_after_close(tmp_path):
    rng = np.random.default_rng(6)
    db = build_db(_synth_lines(rng, n_lines=2, n_events=30),
                  str(tmp_path / "trace.db"))
    before = open(db.path, "rb").read()
    reader = TraceDB(db.path)
    assert len(reader.starts(0)) == 30
    reader.close()                      # open-then-closed: re-merge safe
    build_db(db.path, db.path)
    assert open(db.path, "rb").read() == before
    db.close()


# ---------------------------------------------------------------------------
# pipeline wiring: aggregate(trace_pyramid=True) writes the pyramid, and
# serial vs process drivers produce byte-identical trace.pyr
# ---------------------------------------------------------------------------
def test_aggregate_trace_pyramid_driver_identical(tmp_path):
    from repro.core.aggregate import aggregate
    from tests.test_aggregate import write_rank_profiles
    paths, _ = write_rank_profiles(tmp_path)
    traces = [p.replace(".rpro", ".rtrc") for p in paths]
    blobs = []
    for tag, n_ranks in (("serial", 1), ("procs", 3)):
        db = aggregate(paths, str(tmp_path / tag), n_ranks=n_ranks,
                       n_threads=2, trace_paths=traces, trace_pyramid=True)
        pyr_path = pyramid_path_for(db.trace_db_path())
        assert os.path.exists(pyr_path)
        with TracePyramid(pyr_path) as pyr:
            assert len(pyr) == len(traces)
        blobs.append(open(pyr_path, "rb").read())
    assert blobs[0] == blobs[1]


# ---------------------------------------------------------------------------
# satellite regressions: default windows with unsorted pre-merge lines
# ---------------------------------------------------------------------------
def _serving_db():
    frames = [Frame("root", "<program root>"),
              Frame(HOST, "request:r0", "<serving>", 0),
              Frame(HOST, "phase:prefill", "<serving>", 0),
              Frame(HOST, "fn", "app.py", 3)]
    return SynthDB(frames, np.array([-1, 0, 1, 2], np.int64))


def _unsorted_line(kind="cpu"):
    # first start is NOT the minimum: a default window derived from
    # starts[0] begins at 50 and silently drops the [10, 40) event
    ident = {"rank": 0, "type": kind,
             ("thread" if kind == "cpu" else "stream"): 0}
    return TraceData(ident, np.array([50, 10, 80], np.int64),
                     np.array([70, 40, 95], np.int64),
                     np.array([3, 3, 2], np.int64))


def test_default_window_unsorted_line_summary_and_raster():
    sdb = _serving_db()
    lines = [_unsorted_line()]
    assert summary(lines, sdb, depth=3, top=10) \
        == summary(lines, sdb, t0=10, t1=95, depth=3, top=10)
    ref = rasterize(lines, sdb.parents, t0=10, t1=95, width=17, depth=3)
    got = rasterize(lines, sdb.parents, width=17, depth=3)
    np.testing.assert_array_equal(ref.pixels, got.pixels)


def test_default_window_unsorted_line_request_attribution():
    sdb = _serving_db()
    lines = [_unsorted_line("gpu")]
    rows = stats.request_attribution(lines, sdb)
    assert rows == stats.request_attribution(lines, sdb, t0=10, t1=95)
    # the [10, 40) event attributes: r0 gets all 65 busy ns
    assert rows == [("r0", 65.0, {"prefill": 65.0})]


def test_default_window_unsorted_line_top_hot_loops():
    frames = [Frame("root", "<program root>"),
              Frame(PLACEHOLDER, "kernel:k", "0", 0),
              Frame(GPU_OP, "<gpu op k>", "0", 0),
              Frame(GPU_FUNC, "k", "k.py", 1),
              Frame(GPU_LOOP, "loop", "k.py", 2),
              Frame(GPU_OP, "FMA", "k.py", 3)]
    parents = np.array([-1, 0, 1, 2, 3, 4], np.int64)
    samples = np.zeros((len(frames), 1))
    samples[3] = samples[5] = 8.0

    class _Db(SynthDB):
        stats = {"sum": samples}

        def metric_id(self, name):
            assert name == "gpu_inst/samples"
            return 0

    db = _Db(frames, parents)
    td = TraceData({"rank": 0, "type": "gpu", "stream": 0},
                   np.array([50, 10], np.int64),
                   np.array([70, 40], np.int64),
                   np.array([1, 1], np.int64))
    rows = stats.top_hot_loops([td], db)
    assert rows == stats.top_hot_loops([td], db, t0=10, t1=70)
    # all 50 busy ns prorated onto the single interior op
    assert rows == [("k", "loop", "k.py:3", "FMA", 8.0, 50.0)]


# ---------------------------------------------------------------------------
# satellite regression: filter clips straddling events to [t0, t1)
# ---------------------------------------------------------------------------
def test_filter_clips_straddling_events():
    sdb = _serving_db()
    td = TraceData({"rank": 0, "thread": 0, "type": "cpu"},
                   np.array([0, 35, 90], np.int64),
                   np.array([100, 55, 120], np.int64),
                   np.array([3, 2, 3], np.int64))
    cut = apply_filter([td], TraceFilter(t0=30, t1=60))
    np.testing.assert_array_equal(cut[0].starts, [30, 35])
    np.testing.assert_array_equal(cut[0].ends, [60, 55])
    # so filter-then-default-window == explicit window on the original
    assert summary(cut, sdb, depth=3, top=10) \
        == summary([td], sdb, t0=30, t1=60, depth=3, top=10)


# ---------------------------------------------------------------------------
# satellite regression: vectorized request_spans == quadratic reference
# ---------------------------------------------------------------------------
def test_request_spans_matches_quadratic_reference():
    rng = np.random.default_rng(11)
    n_req = 5
    frames = [Frame("root", "<program root>")]
    parents = [-1]
    for r in range(n_req):
        frames.append(Frame(HOST, f"request:r{r}", "<serving>", 0))
        parents.append(0)
        frames.append(Frame(HOST, "phase:" + ("decode" if r % 2
                                              else "prefill"),
                            "<serving>", 0))
        parents.append(2 * r + 1)
    sdb = SynthDB(frames, np.asarray(parents, np.int64))
    lines = []
    for k in range(3):
        n = 200
        starts = np.sort(rng.integers(0, 10_000, n))
        lines.append(TraceData(
            {"rank": k, "type": "gpu", "stream": k}, starts,
            starts + rng.integers(1, 500, n),
            rng.integers(-2, len(frames) + 2, n)))     # incl. out-of-range

    req, ph = stats.window_labels(sdb)
    ref = {}
    for td in lines:                     # the old O(unique x events) scan
        for g in np.unique(np.asarray(td.ctx)):
            if g < 0 or g >= len(req) or req[int(g)] is None:
                continue
            sel = np.asarray(td.ctx) == g
            key = (req[int(g)], ph[int(g)] or "other")
            s0 = int(np.asarray(td.starts)[sel].min())
            e1 = int(np.asarray(td.ends)[sel].max())
            cur = ref.get(key)
            ref[key] = ((min(cur[0], s0), max(cur[1], e1)) if cur
                        else (s0, e1))
    got = stats.request_spans(lines, sdb)
    assert got == ref and len(got) > 0
