"""System-level integration: train driver (with checkpoint/resume), serve
driver (with the §8.4 derived-metric workflow), trace format, dry-run units.
"""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.models import transformer as T


OPTS = T.ModelOptions(q_chunk=16, kv_chunk=16, ssm_chunk=8, loss_chunk=16)


def test_train_driver_runs_and_checkpoints(tmp_path):
    from repro.launch.train import train
    cfg = get_config("xlstm-125m").reduced()
    shape = ShapeConfig("t", 32, 2, "train")
    _, hist, _ = train(cfg, shape, n_steps=4, ckpt_dir=str(tmp_path),
                       ckpt_every=2, opts=OPTS, log_every=1)
    assert all(np.isfinite(h["loss"]) for h in hist)
    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(str(tmp_path)).latest_step() == 4


def test_train_driver_resume_continues(tmp_path):
    from repro.launch.train import train
    cfg = get_config("qwen2-1.5b").reduced()
    shape = ShapeConfig("t", 32, 2, "train")
    train(cfg, shape, n_steps=3, ckpt_dir=str(tmp_path), ckpt_every=3,
          opts=OPTS, log_every=1)
    # resume: starts from step 3, runs to 5
    _, hist, _ = train(cfg, shape, n_steps=5, ckpt_dir=str(tmp_path),
                       ckpt_every=5, opts=OPTS, resume=True, log_every=1)
    assert hist[0]["step"] >= 3


def test_train_driver_deterministic_data(tmp_path):
    """Same seed -> identical loss trajectory (restart reproducibility)."""
    from repro.launch.train import train
    cfg = get_config("xlstm-125m").reduced()
    shape = ShapeConfig("t", 32, 2, "train")
    _, h1, _ = train(cfg, shape, n_steps=3, opts=OPTS, seed=9, log_every=1)
    _, h2, _ = train(cfg, shape, n_steps=3, opts=OPTS, seed=9, log_every=1)
    assert [h["loss"] for h in h1] == pytest.approx(
        [h["loss"] for h in h2], rel=1e-6)


def test_train_with_profiling(tmp_path):
    from repro.launch.train import train
    cfg = get_config("xlstm-125m").reduced()
    shape = ShapeConfig("t", 32, 2, "train")
    _, _, paths = train(cfg, shape, n_steps=2, opts=OPTS,
                        profile_dir=str(tmp_path / "prof"), log_every=1)
    assert paths and "cpu_0" in paths
    from repro.core.profmt import read_profile
    p = read_profile(paths["cpu_0"])
    inv = p.metrics.index("gpu_kernel/invocations")
    assert sum(v for m, v in zip(p.value_mids, p.values) if m == inv) == 2
    assert any(f.kind == "gpu_op" for f in p.frames), \
        "fine-grained attribution below the train_step placeholder"


def test_serve_driver_and_sync_diff(tmp_path):
    """§8.4.1 reproduction: redundant syncs found via derived metric."""
    from repro.launch.serve import serve
    from repro.core.aggregate import aggregate
    from repro.core.derived import SYNC_DIFF, database_columns
    cfg = get_config("qwen2-1.5b").reduced()
    toks, paths = serve(cfg, n_requests=2, batch=2, prompt_len=16,
                        gen_len=4, profile_dir=str(tmp_path / "prof"),
                        redundant_sync=True)
    assert toks.shape == (2, 4)
    profs = [v for k, v in paths.items()
             if k.startswith("cpu_") and "trace" not in k]
    db = aggregate(profs, str(tmp_path / "db"), n_ranks=1, n_threads=1)
    cols = database_columns(db)
    diff = SYNC_DIFF.evaluate(cols)
    # the global root shows sync_count > kernel_count
    assert diff[0] > 0, "redundant syncs must be visible in the derived metric"


def test_trace_out_of_order_sorted(tmp_path):
    from repro.core.trace import TraceWriter, read_trace
    p = str(tmp_path / "t.rtrc")
    tw = TraceWriter(p, {"rank": 0})
    tw.append(100, 110, 1)
    tw.append(50, 60, 2)    # out of order (§4.4)
    tw.append(200, 210, 3)
    tw.close()
    assert tw.out_of_order
    td = read_trace(p)
    assert list(td.starts) == [50, 100, 200]


def test_input_specs_all_cells_no_alloc():
    """input_specs builds ShapeDtypeStructs for every applicable cell
    without touching devices."""
    from repro.configs import list_configs
    from repro.configs.base import shape_applicable
    from repro.launch.specs import input_specs
    for arch in list_configs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if not shape_applicable(cfg, shape)[0]:
                continue
            specs = input_specs(cfg, shape, plan=None)
            leaves = jax.tree.leaves(specs,
                                     is_leaf=lambda x: isinstance(
                                         x, jax.ShapeDtypeStruct))
            assert leaves
            for leaf in leaves:
                assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, sname)
            if shape.kind == "train":
                b = specs["batch"]
                total = (b["tokens"].shape if "tokens" in b
                         else b["embeds"].shape)
                assert total[0] == shape.global_batch


def test_model_flops_convention():
    from repro.core.roofline import model_flops
    cfg = get_config("qwen2-1.5b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.n_active_params()
    assert tr == pytest.approx(6 * n * 4096 * 256)
    assert pf == pytest.approx(2 * n * 32768 * 32)
    assert dc == pytest.approx(2 * n * 128)


def test_roofline_report_terms():
    from repro.core.roofline import analyze
    hlo = "HloModule m\n\nENTRY %main (x: f32[8]) -> f32[8] {\n" \
          "  ROOT %x = f32[8]{0} parameter(0)\n}\n"
    rep = analyze("t", "mesh", 4, {"flops": 197e12, "bytes accessed": 0.0},
                  hlo_text=hlo, model_flops_total=4 * 197e12)
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.dominant == "compute"
    assert rep.mfu == pytest.approx(1.0)
    assert rep.useful_ratio == pytest.approx(1.0)
