"""Hardware-counter kernel measurement (paper §6; repro.counters):
taxonomy, multiplex scheduling, replay/single-pass collection, channel
transport, aggregation round-trip, and the derived counter columns."""
import os

import numpy as np
import pytest

from repro.core.aggregate import aggregate
from repro.core.cct import CCT, Frame, HOST, PLACEHOLDER
from repro.core.metrics import (GPU_COUNTER_KIND, GPU_COUNTER_METRICS,
                                default_registry)
from repro.core.profmt import write_profile
from repro.counters import (ALL_COUNTERS, CATALOG, COUNTER_INDEX,
                            CounterCollector, DOMAIN_CAPACITY,
                            build_schedule, optimal_passes, resolve,
                            static_counters)

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


@pytest.fixture(scope="module")
def compiled():
    def f(x):
        return jnp.tanh(x @ x.T).sum()
    x = jnp.ones((64, 64))
    return jax.jit(f).lower(x).compile(), x


# ---------------------------------------------------------------------------
# taxonomy + scheduler
# ---------------------------------------------------------------------------
def test_catalog_matches_metric_kind():
    assert tuple(CATALOG) == GPU_COUNTER_METRICS
    reg = default_registry()
    assert reg.kind(GPU_COUNTER_KIND).metrics == GPU_COUNTER_METRICS


def test_resolve_rejects_unknown_and_dedupes():
    with pytest.raises(KeyError):
        resolve(["flops", "nope"])
    assert [c.name for c in resolve(["flops", "flops", "hbm_bytes"])] == \
        ["flops", "hbm_bytes"]


@pytest.mark.parametrize("request_", [
    ("flops",),
    ("flops", "hbm_bytes", "active_ns"),
    ("flops", "mxu_flops", "transcendental_ops"),          # compute cap 2
    ("hbm_read_bytes", "hbm_write_bytes", "hbm_bytes"),    # memory cap 2
    ("ici_wire_bytes", "collective_invocations"),          # collective cap 1
    ALL_COUNTERS,
])
def test_schedule_covers_request_in_optimal_passes(request_):
    sched = build_schedule(request_)
    # full coverage: every requested counter appears in exactly one group
    placed = [c for g in sched.groups for c in g.counters]
    assert sorted(placed) == sorted(sched.requested)
    assert sched.coverage() == frozenset(request_) | frozenset(sched.free)
    # every group respects every domain capacity
    for g in sched.groups:
        per_dom = {}
        for c in g.counters:
            d = CATALOG[c].domain
            per_dom[d] = per_dom.get(d, 0) + 1
        assert all(n <= DOMAIN_CAPACITY[d] for d, n in per_dom.items())
    # pass count: <= the acceptance ceiling (#groups) and == the domain
    # lower bound, i.e. first-fit is optimal here
    assert sched.n_passes <= max(len(sched.groups), 1)
    assert sched.n_passes == optimal_passes(request_)


def test_schedule_round_robin_and_free_counters():
    sched = build_schedule(ALL_COUNTERS)
    assert sched.multiplexed
    seen = [sched.group_for(i).index for i in range(2 * len(sched.groups))]
    assert seen == [0, 1] * len(sched.groups)
    assert set(sched.free) == {"elapsed_ns", "replay_passes"}


# ---------------------------------------------------------------------------
# collection: replay determinism, single-pass equivalence
# ---------------------------------------------------------------------------
def _totals(collector, mod, n, duration_ns=10_000):
    tot = np.zeros(len(GPU_COUNTER_METRICS))
    for _ in range(n):
        tot += collector.read(mod, duration_ns)
    return tot


def test_replay_deterministic_and_single_pass_equiv(compiled):
    from repro.core.structure import parse_hlo
    comp, _ = compiled
    mod = parse_hlo(comp.as_text(), name="f")

    # non-multiplexed set (1 group): replay == single-pass, bitwise
    small = ["flops", "hbm_bytes", "active_ns"]
    assert not build_schedule(small).multiplexed
    r1 = _totals(CounterCollector(small, replay=True), mod, 5)
    r2 = _totals(CounterCollector(small, replay=True), mod, 5)
    s1 = _totals(CounterCollector(small, replay=False), mod, 5)
    np.testing.assert_array_equal(r1, r2)   # deterministic
    np.testing.assert_array_equal(r1, s1)   # replay == single pass

    # multiplexed set: single-pass round-robin extrapolation equals the
    # replay totals whenever invocations are a multiple of the groups
    # (identical executions), except for the pass bookkeeping
    sched = build_schedule(ALL_COUNTERS)
    n = 3 * sched.n_passes
    rep = _totals(CounterCollector(ALL_COUNTERS, replay=True), mod, n)
    sgl = _totals(CounterCollector(ALL_COUNTERS, replay=False), mod, n)
    ip = COUNTER_INDEX["replay_passes"]
    assert rep[ip] == n * sched.n_passes and sgl[ip] == n
    mask = np.arange(len(rep)) != ip
    np.testing.assert_allclose(rep[mask], sgl[mask], rtol=1e-12)


def test_static_counters_calibrate_to_cost_analysis(compiled):
    from repro.core.structure import parse_hlo
    comp, _ = compiled
    cost = comp.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    mod = parse_hlo(comp.as_text(), name="f")
    vec = static_counters(mod, dict(cost))
    fr, _ = mod.cost_scale()
    assert vec[COUNTER_INDEX["flops"]] == \
        pytest.approx(float(cost["flops"]) * fr)
    i_r, i_w, i_t = (COUNTER_INDEX[k] for k in
                     ("hbm_read_bytes", "hbm_write_bytes", "hbm_bytes"))
    assert vec[i_t] == pytest.approx(vec[i_r] + vec[i_w])
    assert vec[COUNTER_INDEX["inst_executed"]] > 0
    assert vec[COUNTER_INDEX["active_ns"]] > 0
    # the per-module cache is keyed by the calibration input: reading
    # uncalibrated then calibrated again must reproduce both exactly
    uncal = static_counters(mod)
    recal = static_counters(mod, dict(cost))
    np.testing.assert_array_equal(recal, vec)
    np.testing.assert_array_equal(uncal, static_counters(mod))


# ---------------------------------------------------------------------------
# end-to-end: counters ride the SPSC channels into the CCT
# ---------------------------------------------------------------------------
def test_counters_flow_through_channels(tmp_path, compiled):
    from repro.core.profiler import Profiler
    from repro.core.profmt import read_profile
    comp, x = compiled
    prof = Profiler(str(tmp_path), tracing=True, rng_seed=0, unwind=False)
    sched = prof.enable_counters(["flops", "hbm_bytes", "elapsed_ns"])
    assert sched.n_passes == 1
    mid = prof.register_module("f", comp.as_text(),
                               cost=comp.cost_analysis())
    with prof:
        for _ in range(4):
            with prof.dispatch("kernel", "f", stream=0, module_id=mid,
                               duration_ns=10_000):
                jax.block_until_ready(comp(x))
    assert prof._monitor.stats["counter_records"] == 4
    paths = prof.write()
    p = read_profile(paths["cpu_0"])

    def total(name):
        i = p.metrics.index(name)
        return sum(v for m, v in zip(p.value_mids, p.values) if m == i)

    assert total("gpu_counter/elapsed_ns") == 40_000
    assert total("gpu_counter/replay_passes") == 4
    assert total("gpu_counter/flops") > 0
    # not requested -> never collected
    assert total("gpu_counter/mxu_flops") == 0
    # per-stream GPU profile carries the same counters
    g = read_profile(paths["gpu_0"])
    ie = g.metrics.index("gpu_counter/elapsed_ns")
    assert sum(v for m, v in zip(g.value_mids, g.values) if m == ie) == 40_000


def test_replay_run_totals_deterministic(tmp_path, compiled):
    """Two identical replay-mode profiling runs write identical counter
    values (serialized replay's defining property)."""
    from repro.core.profiler import Profiler
    from repro.core.profmt import read_profile

    comp, x = compiled

    def run(sub):
        out = tmp_path / sub
        prof = Profiler(str(out), tracing=False, rng_seed=0, unwind=False)
        prof.enable_counters(ALL_COUNTERS, replay=True)
        mid = prof.register_module("f", comp.as_text())
        with prof:
            for _ in range(3):
                with prof.dispatch("kernel", "f", stream=0, module_id=mid,
                                   duration_ns=5_000):
                    jax.block_until_ready(comp(x))
        paths = prof.write()
        return read_profile(paths["cpu_0"])

    p1, p2 = run("a"), run("b")
    np.testing.assert_array_equal(p1.values, p2.values)
    np.testing.assert_array_equal(p1.value_mids, p2.value_mids)


# ---------------------------------------------------------------------------
# aggregation round-trip + derived columns
# ---------------------------------------------------------------------------
def write_counter_rank_profiles(tmp_path, n=4):
    """Fixture: n ranks, one kernel context, hand-picked counter values.

    Rank r carries (r+1) x BASE, so sums/mins/maxes are hand-computable.
    BASE is chosen to make the derived columns round numbers:
    occupancy 0.25, flop efficiency 0.5, bytes/flop 2.0, passes 2.
    """
    reg = default_registry()
    ckind = reg.kind("gpu_counter")
    kkind = reg.kind("gpu_kernel")
    base = np.zeros(len(GPU_COUNTER_METRICS))
    base[COUNTER_INDEX["elapsed_ns"]] = 1_000.0
    base[COUNTER_INDEX["active_ns"]] = 250.0
    base[COUNTER_INDEX["flops"]] = 98_500_000.0    # 0.5 * 197e3 * 1e3
    base[COUNTER_INDEX["hbm_bytes"]] = 197_000_000.0
    base[COUNTER_INDEX["replay_passes"]] = 2.0
    paths = []
    for r in range(n):
        cct = CCT()
        main = cct.insert_path([Frame(HOST, "main", "app.py", 1)])
        ph = cct.get_or_insert(main,
                               Frame(PLACEHOLDER, "kernel:train", "0", 0))
        ph.metrics.add(kkind, "invocations", 1)
        ph.metrics.add(kkind, "time_ns", 1_000.0)
        vec = base * (r + 1)
        # passes-per-invocation stays 2 on every rank (it is bookkeeping,
        # not workload, so it does not scale with the rank factor)
        vec[COUNTER_INDEX["replay_passes"]] = 2.0
        ph.metrics.add_vec(ckind, vec)
        p = str(tmp_path / f"profile_r{r}_t0.rpro")
        write_profile(p, cct, reg,
                      {"rank": r, "thread": 0, "type": "cpu"}, [])
        paths.append(p)
    return paths, base


def test_counter_kind_survives_aggregate_bitwise(tmp_path):
    paths, base = write_counter_rank_profiles(tmp_path, n=4)
    db1 = aggregate(paths, str(tmp_path / "db1"), n_ranks=4, n_threads=2)
    db2 = aggregate(paths, str(tmp_path / "db2"), n_ranks=4, n_threads=2)
    for s in db1.stats:
        np.testing.assert_array_equal(db1.stats[s], db2.stats[s])
    # byte-identical sparse cubes and stats file across the two runs
    for fn in ("stats.npz", "metrics.cms", "metrics.pms"):
        b1 = open(os.path.join(db1.out_dir, fn), "rb").read()
        b2 = open(os.path.join(db2.out_dir, fn), "rb").read()
        assert b1 == b2, f"{fn} differs between identical aggregations"
    # and the values are the exact fold of the rank inputs
    ph = [i for i, f in enumerate(db1.frames) if f.kind == PLACEHOLDER][0]
    for name in ("elapsed_ns", "flops", "hbm_bytes"):
        mid = db1.metric_id(f"gpu_counter/{name}")
        expect = base[COUNTER_INDEX[name]]
        assert db1.stats["sum"][ph, mid] == expect * (1 + 2 + 3 + 4)
        assert db1.stats["min"][ph, mid] == expect
        assert db1.stats["max"][ph, mid] == expect * 4


def test_derived_counter_columns_hand_computed(tmp_path):
    from repro.core.derived import (ACHIEVED_OCCUPANCY, BYTES_PER_FLOP,
                                    FLOP_EFFICIENCY, REPLAY_PASS_COUNT,
                                    database_columns)
    paths, _ = write_counter_rank_profiles(tmp_path, n=4)
    db = aggregate(paths, str(tmp_path / "db"), n_ranks=2, n_threads=2)
    cols = database_columns(db, "sum")
    ph = [i for i, f in enumerate(db.frames) if f.kind == PLACEHOLDER][0]
    # sums scale numerator and denominator alike, so the hand values hold
    assert ACHIEVED_OCCUPANCY.evaluate(cols)[ph] == pytest.approx(0.25)
    assert FLOP_EFFICIENCY.evaluate(cols)[ph] == pytest.approx(0.5)
    assert BYTES_PER_FLOP.evaluate(cols)[ph] == pytest.approx(2.0)
    assert REPLAY_PASS_COUNT.evaluate(cols)[ph] == pytest.approx(2.0)
    # zero-division policy: the root has cpu time only in these fixtures
    bare = [i for i, f in enumerate(db.frames) if f.kind == HOST][0]
    assert BYTES_PER_FLOP.evaluate(cols)[bare] != np.inf


def test_viewer_counter_table_and_traceview_join(tmp_path):
    from repro.core import viewer
    from repro.core.trace import TraceData
    from repro.traceview.stats import top_kernel_counters
    paths, _ = write_counter_rank_profiles(tmp_path, n=4)
    db = aggregate(paths, str(tmp_path / "db"), n_ranks=2, n_threads=2)
    txt = viewer.counter_table(db, top=5)
    assert "COUNTERS" in txt and "kernel:train" in txt
    assert "0.250" in txt           # occupancy column
    ph = [i for i, f in enumerate(db.frames)
          if f.kind == PLACEHOLDER][0]
    lines = [TraceData({"rank": 0, "stream": 0, "type": "gpu"},
                       np.array([0, 100]), np.array([80, 150]),
                       np.array([ph, ph]))]
    rows = top_kernel_counters(lines, db, t0=0, t1=150, k=3)
    assert rows and rows[0][0] == "<gpu op kernel:train>"
    assert rows[0][1] == 130.0
    assert rows[0][2]["occupancy"] == pytest.approx(0.25)
    assert rows[0][2]["replay_passes"] == pytest.approx(2.0)
