"""Traceview subsystem (paper §4.4, §7): merged trace.db, depth×time
raster, interval statistics, filters — plus the TraceWriter interleaved
append regression the merge depends on."""
import os

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.blame import blame_gpu_idleness
from repro.core.cct import Frame
from repro.core.trace import TraceData, TraceWriter, read_trace
from repro.traceview import (TraceDB, TraceFilter, apply_filter,
                             blame_over_time, build_db, interval_profile,
                             merge_intervals, occupancy, rasterize, render,
                             subtree_mask, summary, top_kernels,
                             windowed_blame)


# ---------------------------------------------------------------------------
# TraceWriter: interleaved append / append_many (ISSUE 2 satellite)
# ---------------------------------------------------------------------------
SCENARIOS = {
    # chunk then a scalar append earlier than the chunk's LAST start: the
    # writer must compare against the chunk tail, not a stale last-start
    "many_then_earlier_append": ([("many", [10, 20, 30]), ("one", 15)], True),
    "many_then_later_append": ([("many", [10, 20, 30]), ("one", 30)], False),
    "append_then_earlier_many": ([("one", 50), ("many", [40, 60])], True),
    "append_then_later_many": ([("one", 50), ("many", [50, 60])], False),
    "unsorted_chunk": ([("many", [10, 5, 30])], True),
    "many_many_boundary": ([("many", [10, 20]), ("many", [15, 30])], True),
    "in_order_interleave": ([("many", [10, 20]), ("one", 30),
                             ("many", [40]), ("one", 50)], False),
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_tracewriter_interleaved_append_apis(tmp_path, name):
    ops, want_ooo = SCENARIOS[name]
    mixed = TraceWriter(str(tmp_path / "mixed.rtrc"), {"rank": 0})
    pure = TraceWriter(str(tmp_path / "pure.rtrc"), {"rank": 0})
    flat = []
    for kind, v in ops:
        if kind == "many":
            mixed.append_many(v, [x + 1 for x in v], [7] * len(v))
            flat.extend(v)
        else:
            mixed.append(v, v + 1, 7)
            flat.append(v)
    for s in flat:
        pure.append(s, s + 1, 7)
    assert mixed.out_of_order == want_ooo
    assert pure.out_of_order == want_ooo
    mixed.close()
    pure.close()
    # byte-identical to the equivalent pure-append sequence
    assert open(mixed.path, "rb").read() == open(pure.path, "rb").read()
    td = read_trace(mixed.path)
    assert list(td.starts) == sorted(flat)   # reader sorts when flagged


# ---------------------------------------------------------------------------
# fixtures: a small deterministic tree + traces
# ---------------------------------------------------------------------------
class SynthDB:
    def __init__(self, frames, parents):
        self.frames = frames
        self.parents = parents


@pytest.fixture
def tiny():
    frames = [Frame("root", "<program root>"),
              Frame("host", "main", "app.py", 1),
              Frame("host", "step", "app.py", 10),
              Frame("placeholder", "kernel:train", "0", 0),
              Frame("host", "other", "app.py", 20)]
    parents = np.array([-1, 0, 1, 2, 1])
    cpu = TraceData({"rank": 0, "thread": 0, "type": "cpu"},
                    np.array([0, 50, 80]), np.array([50, 80, 100]),
                    np.array([2, 4, 2]))
    gpu = TraceData({"rank": 0, "stream": 0, "type": "gpu"},
                    np.array([10, 60]), np.array([40, 70]),
                    np.array([3, 3]))
    return SynthDB(frames, parents), [cpu, gpu]


def write_lines(tmp_path, lines):
    paths = []
    for td in lines:
        ident = td.identity
        tag = f"r{ident['rank']}_" + (f"t{ident.get('thread', 0)}"
                                      if ident["type"] == "cpu"
                                      else f"s{ident.get('stream', 0)}")
        tw = TraceWriter(str(tmp_path / f"trace_{tag}.rtrc"), ident)
        tw.append_many(td.starts, td.ends, td.ctx)
        tw.close()
        paths.append(tw.path)
    return paths


# ---------------------------------------------------------------------------
# trace.db: merge, identity index, mmap reads, idempotence
# ---------------------------------------------------------------------------
def test_tracedb_roundtrip(tmp_path, tiny):
    db, lines = tiny
    write_lines(tmp_path, lines)
    tdb = build_db(str(tmp_path), str(tmp_path / "trace.db"))
    assert len(tdb) == 2 and tdb.n_events == 5
    assert tdb.time_range() == (0, 100)
    # CPU threads order before GPU streams
    assert tdb.lines[0].identity["type"] == "cpu"
    v = tdb.view(0)
    np.testing.assert_array_equal(v.starts, [0, 50, 80])
    np.testing.assert_array_equal(v.ends, [50, 80, 100])
    np.testing.assert_array_equal(v.ctx, [2, 4, 2])


def test_tracedb_sorts_out_of_order_once(tmp_path):
    tw = TraceWriter(str(tmp_path / "a.rtrc"), {"rank": 0, "type": "gpu",
                                                "stream": 0})
    tw.append_many([30, 10, 20], [35, 15, 25], [1, 2, 3])
    tw.close()
    assert tw.out_of_order
    tdb = build_db([tw.path], str(tmp_path / "trace.db"))
    np.testing.assert_array_equal(tdb.starts(0), [10, 20, 30])
    np.testing.assert_array_equal(tdb.ctx(0), [2, 3, 1])


def test_tracedb_merge_idempotent(tmp_path, tiny):
    _, lines = tiny
    paths = write_lines(tmp_path, lines)
    db1 = build_db(paths, str(tmp_path / "one.db"))
    db2 = build_db(db1.path, str(tmp_path / "two.db"))
    assert open(db1.path, "rb").read() == open(db2.path, "rb").read()
    # and merging a mix of db + raw files keeps every line exactly once
    db3 = build_db([db1.path], str(tmp_path / "three.db"))
    assert db3.n_events == db1.n_events
    # in-place re-merge (output == input) must not read truncated pages
    before = open(db1.path, "rb").read()
    build_db(db1.path, db1.path)
    assert open(db1.path, "rb").read() == before


def test_tracedb_empty(tmp_path):
    tdb = build_db([], str(tmp_path / "empty.db"))
    assert len(tdb) == 0 and tdb.n_events == 0
    again = TraceDB(tdb.path)
    assert len(again) == 0


# ---------------------------------------------------------------------------
# raster + render: golden text at two zoom levels
# ---------------------------------------------------------------------------
GOLDEN_FULL = """\
TRACEVIEW  [0, 100)  span=100ns  depth=2  2x20
r0.t0 |aaaaaaaaaabbbbbbaaaa|
r0.s0 |..aaaaaa....aa......|
legend:
  a  78.6%  step @ app.py:10
  b  21.4%  other @ app.py:20"""

GOLDEN_ZOOM = """\
TRACEVIEW  [40, 80)  span=40ns  depth=3  2x20
r0.t0 |bbbbbaaaaaaaaaaaaaaa|
r0.s0 |..........ccccc.....|
legend:
  a  60.0%  other @ app.py:20
  b  20.0%  step @ app.py:10
  c  20.0%  <gpu op kernel:train>"""


def test_raster_golden_two_zooms(tiny):
    db, lines = tiny
    full = render(rasterize(lines, db.parents, width=20, depth=2), db)
    assert full == GOLDEN_FULL
    zoom = render(rasterize(lines, db.parents, t0=40, t1=80, width=20,
                            depth=3), db)
    assert zoom == GOLDEN_ZOOM


def test_raster_nested_events_show_enclosing(tiny):
    """After a nested event ends, the enclosing event shows through —
    what nested cpu_region calls produce."""
    db, _ = tiny
    line = TraceData({"rank": 0, "thread": 0, "type": "cpu"},
                     np.array([0, 20, 50, 55]), np.array([100, 40, 70, 60]),
                     np.array([1, 2, 2, 4]))
    r = rasterize([line], db.parents, t0=0, t1=100, width=10, depth=3)
    # samples at 5,15: outer ctx1; 25,35: nested ctx2; 45: back to ctx1;
    # 55: ctx4 (innermost of three open); 65: ctx2; 75..95: ctx1 again
    assert r.pixels[0].tolist() == [1, 1, 2, 2, 1, 4, 2, 1, 1, 1]


def test_raster_height_budget(tiny):
    db, (cpu, gpu) = tiny
    many = [TraceData({**cpu.identity, "thread": i}, cpu.starts, cpu.ends,
                      cpu.ctx) for i in range(10)]
    r = rasterize(many, db.parents, width=8, height=4, depth=1)
    assert r.pixels.shape[0] <= 4
    assert len(r.labels) == r.pixels.shape[0]


# ---------------------------------------------------------------------------
# interval statistics
# ---------------------------------------------------------------------------
def test_summary_matches_trace_statistic(tmp_path):
    from repro.core import viewer
    from repro.core.aggregate import aggregate
    from tests.test_aggregate import write_rank_profiles
    paths, _ = write_rank_profiles(tmp_path)
    traces = [p.replace(".rpro", ".rtrc") for p in paths]
    out = str(tmp_path / "db")
    db = aggregate(paths, out, n_ranks=1, n_threads=1, trace_paths=traces)
    tds = [read_trace(os.path.join(out, os.path.basename(t)))
           for t in traces]
    for depth in (1, 2):
        ref = dict(viewer.trace_statistic(tds, db, depth=depth, top=10**9))
        got = dict(summary(tds, db, depth=depth, top=10**9))
        assert got == pytest.approx(ref)


def test_summary_groups_same_routine_across_contexts():
    """One function reached via two call paths is one Summary row, like
    trace_statistic."""
    frames = [Frame("root", "<program root>"),
              Frame("host", "a", "app.py", 1), Frame("host", "b", "app.py", 2),
              Frame("host", "work", "app.py", 5),
              Frame("host", "work", "app.py", 5)]
    db = SynthDB(frames, np.array([-1, 0, 0, 1, 2]))
    line = TraceData({"rank": 0, "thread": 0, "type": "cpu"},
                     np.array([0, 20]), np.array([20, 40]), np.array([3, 4]))
    rows = summary([line], db, depth=2, top=1)
    assert rows == [("work @ app.py:5", 1.0)]


def test_aggregate_writes_trace_db(tmp_path):
    from repro.core.aggregate import aggregate
    from tests.test_aggregate import write_rank_profiles
    paths, _ = write_rank_profiles(tmp_path)
    traces = [p.replace(".rpro", ".rtrc") for p in paths]
    db = aggregate(paths, str(tmp_path / "db"), n_ranks=1, n_threads=1,
                   trace_paths=traces)
    tdb = TraceDB(db.trace_db_path())
    assert len(tdb) == len(traces)
    # merged ctx ids are global: renderable against the Database
    r = rasterize(tdb.line_views(), db.parents, width=16, depth=1)
    assert (r.pixels >= -1).all() and (r.pixels < len(db.frames)).all()


def test_interval_profile_window(tiny):
    db, lines = tiny
    prof = interval_profile(lines, len(db.frames), 40, 80)
    # cpu: ctx2 overlaps [40,50)=10, ctx4 [50,80)=30; gpu ctx3 [60,70)=10
    assert prof[2] == 10 and prof[4] == 30 and prof[3] == 10


def test_top_kernels(tiny):
    db, lines = tiny
    rows = top_kernels(lines, db, t0=0, t1=100, k=2)
    assert rows == [("<gpu op kernel:train>", 40.0)]


def test_blame_over_time_matches_core_blame(tiny):
    db, lines = tiny
    bot = blame_over_time(lines, 0, 100, 7)
    ref_blame, ref_idle = blame_gpu_idleness([lines[0]], [lines[1]])
    got = bot[0]
    assert got["idle_ns"].sum() == pytest.approx(ref_idle)
    assert {c: v.sum() for c, v in got["blame"].items()} \
        == pytest.approx(ref_blame)
    w_blame, w_idle = windowed_blame(lines, 0, 100)
    assert w_idle == pytest.approx(ref_idle)
    assert w_blame == pytest.approx(ref_blame)


def test_merge_intervals():
    s, e = merge_intervals([0, 5, 20, 10], [7, 6, 30, 20])
    np.testing.assert_array_equal(s, [0, 10])
    np.testing.assert_array_equal(e, [7, 30])


@given(st.lists(st.tuples(st.integers(0, 500), st.integers(1, 40)),
                min_size=1, max_size=30),
       st.integers(1, 13))
@settings(max_examples=60, deadline=None)
def test_occupancy_sums_to_window(events, nbins):
    """Per line: busy-per-bin sums to total busy, and busy + idle equals
    the window length — for any events and any binning."""
    starts = np.sort(np.array([s for s, _ in events]))
    durs = np.array([d for _, d in events])
    ends = starts + durs
    td = TraceData({"rank": 0, "type": "gpu", "stream": 0}, starts, ends,
                   np.ones(len(starts), np.int64))
    t0, t1 = 0, int(ends.max()) + 7
    busy = occupancy([td], t0, t1, nbins)
    m_s, m_e = merge_intervals(starts, ends)
    total_busy = int((m_e - m_s).sum())
    assert busy.shape == (1, nbins)
    assert busy.sum() == pytest.approx(total_busy)
    idle = (t1 - t0) - busy.sum()
    assert idle == pytest.approx(t1 - t0 - total_busy)
    assert 0 <= idle <= t1 - t0


# ---------------------------------------------------------------------------
# filters
# ---------------------------------------------------------------------------
def test_filter_identity_and_window(tiny):
    db, lines = tiny
    assert [td.identity["type"]
            for td in apply_filter(lines, TraceFilter(types={"gpu"}))] \
        == ["gpu"]
    assert apply_filter(lines, TraceFilter(ranks={3})) == []
    cut = apply_filter(lines, TraceFilter(t0=55, t1=75))
    assert len(cut[0].starts) == 1          # cpu: only the [50,80) event
    np.testing.assert_array_equal(cut[1].starts, [60])


def test_filter_subtree(tiny):
    db, lines = tiny
    mask = subtree_mask(db.parents, 2)
    np.testing.assert_array_equal(mask, [False, False, True, True, False])
    cut = apply_filter(lines, TraceFilter(subtree=2), db.parents)
    np.testing.assert_array_equal(cut[0].ctx, [2, 2])   # ctx4 dropped
    np.testing.assert_array_equal(cut[1].ctx, [3, 3])
    with pytest.raises(ValueError):
        apply_filter(lines, TraceFilter(subtree=2))


# ---------------------------------------------------------------------------
# profiler wiring
# ---------------------------------------------------------------------------
def test_profiler_build_trace_db(tmp_path):
    import itertools
    from repro.core.profiler import Profiler
    ticks = itertools.count(0, 1000)
    prof = Profiler(str(tmp_path / "m"), tracing=True, unwind=False,
                    clock=lambda: next(ticks))
    with prof:
        with prof.dispatch("kernel", "k", stream=0, duration_ns=5000):
            pass
        with prof.cpu_region("prep"):
            pass
    prof.write()
    tdb = TraceDB(prof.build_trace_db())
    assert len(tdb) >= 2                     # cpu thread + gpu stream
    assert tdb.n_events >= 3
