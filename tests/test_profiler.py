"""End-to-end measurement runtime: dispatch -> monitor -> attribution ->
profiles + traces (paper §4.1-§4.6, Fig. 2)."""
import glob
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cct import PLACEHOLDER
from repro.core.profiler import Profiler
from repro.core.profmt import read_profile
from repro.core.sampling import instruction_counts, pc_samples
from repro.core.structure import parse_hlo
from repro.core.trace import read_trace


@pytest.fixture(scope="module")
def compiled():
    def f(x):
        return jnp.tanh(x @ x.T).sum()
    x = jnp.ones((64, 64))
    return jax.jit(f).lower(x).compile(), x


def test_dispatch_attribution(tmp_path, compiled):
    comp, x = compiled
    prof = Profiler(str(tmp_path), tracing=True, rng_seed=0)
    mid = prof.register_module("f", comp.as_text())
    with prof:
        for _ in range(3):
            with prof.dispatch("kernel", "f", stream=0, module_id=mid):
                jax.block_until_ready(comp(x))
        with prof.dispatch("copy", "h2d", stream=1, nbytes=4096):
            pass
    paths = prof.write()
    p = read_profile(paths["cpu_0"])
    inv = p.metrics.index("gpu_kernel/invocations")
    total_inv = sum(v for m, v in zip(p.value_mids, p.values) if m == inv)
    assert total_inv == 3
    cp = p.metrics.index("gpu_copy/bytes")
    assert sum(v for m, v in zip(p.value_mids, p.values) if m == cp) == 4096
    # fine-grained samples attributed under the placeholder
    kinds = [f.kind for f in p.frames]
    assert "gpu_op" in kinds, "PC-sample analogue nodes must exist"
    # placeholder present with stream id
    ph = [f for f in p.frames if f.kind == PLACEHOLDER]
    assert any(f.name == "kernel:f" for f in ph)


def test_per_stream_profiles_and_traces(tmp_path, compiled):
    comp, x = compiled
    prof = Profiler(str(tmp_path), tracing=True, rng_seed=0)
    mid = prof.register_module("f", comp.as_text())
    with prof:
        for s in (0, 1, 2):
            with prof.dispatch("kernel", "f", stream=s, module_id=mid):
                jax.block_until_ready(comp(x))
    paths = prof.write()
    for s in (0, 1, 2):
        assert f"gpu_{s}" in paths
        td = read_trace(paths[f"gpu_trace_{s}"])
        assert len(td.starts) == 1
        assert td.identity["stream"] == s


def test_multithreaded_dispatch(tmp_path, compiled):
    """The Fig. 2 topology: N app threads, one monitor, SPSC only."""
    comp, x = compiled
    prof = Profiler(str(tmp_path), tracing=False, rng_seed=0, unwind=False)
    mid = prof.register_module("f", comp.as_text())
    N, K = 4, 8

    def worker(i):
        for _ in range(K):
            with prof.dispatch("kernel", "f", stream=i, module_id=mid):
                jax.block_until_ready(comp(x))

    with prof:
        ts = [threading.Thread(target=worker, args=(i,)) for i in range(N)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert prof.flush(timeout=30)
    paths = prof.write()
    cpu_paths = [v for k, v in paths.items()
                 if k.startswith("cpu_") and "trace" not in k]
    assert len(cpu_paths) == N
    total = 0
    for p in cpu_paths:
        d = read_profile(p)
        inv = d.metrics.index("gpu_kernel/invocations")
        total += sum(v for m, v in zip(d.value_mids, d.values) if m == inv)
    assert total == N * K, "every dispatch must be attributed exactly once"
    assert prof._monitor.stats["routed"] == prof._monitor.stats["activities"]


def test_pc_samples_proportional(compiled):
    comp, _ = compiled
    mod = parse_hlo(comp.as_text())
    samples = pc_samples(mod, duration_s=1e-3, rate_hz=1e6)
    assert samples, "1k expected samples"
    total = sum(s.count for s in samples)
    assert total == pytest.approx(1000, rel=0.05)
    ops = mod.all_ops()
    # the dot should dominate the samples for a matmul-heavy kernel
    top = max(samples, key=lambda s: s.count)
    assert ops[top.op_index].opcode in ("dot", "fusion")
    # deterministic without rng
    s2 = pc_samples(mod, duration_s=1e-3, rate_hz=1e6)
    assert [(s.op_index, s.count) for s in samples] == \
        [(s.op_index, s.count) for s in s2]


def test_instruction_counts_loop_multiplier():
    import jax
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y
    comp = jax.jit(f).lower(jnp.ones((16, 16))).compile()
    mod = parse_hlo(comp.as_text())
    whiles = [op for op in mod.all_ops() if op.opcode == "while"]
    counts = instruction_counts(mod, {whiles[0].name: 6})
    ops = mod.all_ops()
    body_dots = [s for s in counts
                 if ops[s.op_index].opcode == "dot"]
    assert body_dots and body_dots[0].count == 6


def test_flush_quiesces(tmp_path, compiled):
    comp, x = compiled
    prof = Profiler(str(tmp_path), tracing=True, rng_seed=0)
    mid = prof.register_module("f", comp.as_text())
    prof.start()
    with prof.dispatch("kernel", "f", stream=0, module_id=mid):
        jax.block_until_ready(comp(x))
    assert prof.flush(timeout=20)
    prof.stop()
