"""Wait-free SPSC queue / channel tests (paper §4.1)."""
import threading
from collections import deque

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.channels import (EMPTY, BidirectionalChannel, ChannelSet,
                                 SpscQueue)


def test_fifo_basic():
    q = SpscQueue(4)
    assert q.try_pop() is EMPTY
    assert q.try_push(1) and q.try_push(2) and q.try_push(3) and q.try_push(4)
    assert not q.try_push(5), "queue of capacity 4 must reject the 5th"
    assert [q.try_pop() for _ in range(4)] == [1, 2, 3, 4]
    assert q.try_pop() is EMPTY
    # wraparound
    for i in range(10):
        assert q.try_push(i)
        assert q.try_pop() == i


@given(st.lists(st.one_of(st.integers(0, 1000),
                          st.just("pop")), max_size=200),
       st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_model_based(ops, cap):
    """Queue behaves exactly like a bounded deque."""
    q = SpscQueue(cap)
    model = deque()
    for op in ops:
        if op == "pop":
            got = q.try_pop()
            if model:
                assert got == model.popleft()
            else:
                assert got is EMPTY
        else:
            ok = q.try_push(op)
            assert ok == (len(model) < cap)
            if ok:
                model.append(op)
    assert len(q) == len(model)


def test_threaded_stress():
    """1M items across a producer and a consumer thread, no locks."""
    q = SpscQueue(1024)
    N = 100_000
    out = []

    def producer():
        i = 0
        while i < N:
            if q.try_push(i):
                i += 1

    def consumer():
        while len(out) < N:
            item = q.try_pop()
            if item is not EMPTY:
                out.append(item)

    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start()
    tp.join(timeout=60); tc.join(timeout=60)
    assert out == list(range(N)), "FIFO order must survive concurrency"


def test_bidirectional_channel_roles():
    ch = BidirectionalChannel(8)
    assert ch.operation is ch.forward
    assert ch.activity is ch.backward
    ch.operation.try_push(("I", "P"))
    assert ch.operation.try_pop() == ("I", "P")


def test_channel_set_per_thread():
    cs = ChannelSet()
    chans = {}

    def worker(tid):
        chans[tid] = cs.channel_for(tid)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len({id(c) for c in chans.values()}) == 8
    # stable on re-request
    assert cs.channel_for(3) is chans[3]


def test_push_failure_counts():
    q = SpscQueue(1)
    q.try_push(1)
    q.try_push(2)
    q.try_push(3)
    assert q.push_failures == 2
    assert q.pushes == 1


# ---------------------------------------------------------------------------
# batch ops (ISSUE 1: amortize per-item Python overhead)
# ---------------------------------------------------------------------------
def test_push_many_pop_many_fifo():
    q = SpscQueue(8)
    assert q.try_push_many(list(range(5))) == 5
    assert q.try_push_many([5, 6, 7, 8, 9]) == 3, "only 3 slots left"
    assert q.try_pop_many() == [0, 1, 2, 3, 4, 5, 6, 7]
    assert q.try_pop_many() == []
    # wraparound across the ring boundary
    assert q.try_push_many([10, 11, 12, 13, 14, 15]) == 6
    assert q.try_pop_many(limit=2) == [10, 11]
    assert q.try_push_many([16, 17, 18, 19]) == 4
    assert q.try_pop_many() == [12, 13, 14, 15, 16, 17, 18, 19]


def test_push_many_full_and_counters():
    q = SpscQueue(2)
    assert q.try_push_many([1, 2, 3]) == 2
    assert q.push_failures == 1   # partial batch counts one failure
    assert q.try_push_many([4]) == 0
    assert q.push_failures == 2
    assert q.pushes == 2
    assert q.try_pop_many() == [1, 2]
    assert q.pops == 2


def test_batch_interleaves_with_scalar_ops():
    q = SpscQueue(16)
    q.try_push(0)
    q.try_push_many([1, 2, 3])
    q.try_push(4)
    assert q.try_pop() == 0
    assert q.try_pop_many(limit=3) == [1, 2, 3]
    assert q.try_pop() == 4


def test_batch_threaded_stress():
    """Producer pushes batches, consumer pops batches: FIFO survives."""
    q = SpscQueue(512)
    N = 50_000
    out = []

    def producer():
        i = 0
        while i < N:
            i += q.try_push_many(list(range(i, min(i + 64, N))))

    def consumer():
        while len(out) < N:
            out.extend(q.try_pop_many(128))

    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start()
    tp.join(timeout=60); tc.join(timeout=60)
    assert out == list(range(N))


# ---------------------------------------------------------------------------
# RecordRing: the dispatch hot path's per-thread ring (ISSUE 10)
# ---------------------------------------------------------------------------
def _ring():
    from repro.core.channels import RecordRing
    return RecordRing


def test_record_ring_fifo_and_bounds():
    ring = _ring()(4)
    assert ring.empty and len(ring) == 0
    assert ring.read_batch() is None
    for i in range(4):
        assert ring.try_append(("rec", i))
    assert not ring.try_append(("rec", 4)), "full ring must refuse"
    assert ring.full_waits == 1
    payloads, lane, epoch = ring.read_batch()
    assert payloads == [("rec", i) for i in range(4)]
    assert lane.shape == (4, 3) and epoch == 1
    assert ring.empty
    # wraparound across the capacity boundary preserves FIFO order
    for i in range(10):
        assert ring.try_append_timed(i, 10 * i, 10 * i + 5, i)
        payloads, lane, _ = ring.read_batch()
        assert payloads == [i]
        assert lane.tolist() == [[10 * i, 10 * i + 5, i]]


def test_record_ring_lane_rows_ride_the_batch():
    """Timed records carry their (t_start, t_end, ctx) row in the numpy
    trace lane, gathered per batch as an owned copy aligned with the
    payload list — the batched-trace-append contract."""
    ring = _ring()(8)
    ring.try_append(("op", 0))                 # untimed: stale lane row
    ring.try_append_timed(("act", 0), 100, 150, 7)
    ring.try_append_timed(("act", 1), 200, 260, 9)
    payloads, lane, _ = ring.read_batch()
    assert [p[0] for p in payloads] == ["op", "act", "act"]
    assert lane[1:].tolist() == [[100, 150, 7], [200, 260, 9]]
    # the gather is a copy: later appends must not mutate a drained batch
    snapshot = lane.copy()
    for i in range(8):
        ring.try_append_timed(("act", 2 + i), 300 + i, 300 + i, 1)
    assert (lane == snapshot).all()


def test_record_ring_batch_limit_and_epochs():
    ring = _ring()(16)
    for i in range(10):
        ring.try_append(i)
    p1, _, e1 = ring.read_batch(limit=4)
    p2, _, e2 = ring.read_batch(limit=4)
    p3, _, e3 = ring.read_batch(limit=4)
    assert (p1, p2, p3) == ([0, 1, 2, 3], [4, 5, 6, 7], [8, 9])
    assert (e1, e2, e3) == (1, 2, 3)
    assert ring.appends == 10 and ring.reads == 10


def test_record_ring_spsc_threaded_stress():
    """One producer thread, one consumer thread, a ring much smaller
    than the record count: every record arrives exactly once, in order,
    with its lane row still aligned to its payload."""
    ring = _ring()(256)
    N = 100_000
    got, got_lane = [], []

    def producer():
        i = 0
        while i < N:
            if ring.try_append_timed(i, i, i + 1, i % 7):
                i += 1

    def consumer():
        while len(got) < N:
            batch = ring.read_batch(128)
            if batch is None:
                continue
            payloads, lane, _ = batch
            got.extend(payloads)
            got_lane.append(lane)

    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start()
    tp.join(timeout=60); tc.join(timeout=60)
    assert got == list(range(N))
    lane = np.concatenate(got_lane)
    assert lane.shape == (N, 3)
    assert lane[:, 0].tolist() == list(range(N))
    assert (lane[:, 1] - lane[:, 0] == 1).all()
    assert (lane[:, 2] == np.arange(N) % 7).all()


def test_ring_set_registration_and_reuse():
    from repro.core.channels import RingSet
    rings = RingSet(capacity=8)
    a = rings.ring_for("t1")
    assert rings.ring_for("t1") is a            # one ring per thread
    b = rings.ring_for("t2")
    assert b is not a
    assert [tid for tid, _ in rings.items()] == ["t1", "t2"]
    assert a._capacity == 8
