"""Wait-free SPSC queue / channel tests (paper §4.1)."""
import threading
from collections import deque

import pytest
from hypothesis_compat import given, settings, st

from repro.core.channels import (EMPTY, BidirectionalChannel, ChannelSet,
                                 SpscQueue)


def test_fifo_basic():
    q = SpscQueue(4)
    assert q.try_pop() is EMPTY
    assert q.try_push(1) and q.try_push(2) and q.try_push(3) and q.try_push(4)
    assert not q.try_push(5), "queue of capacity 4 must reject the 5th"
    assert [q.try_pop() for _ in range(4)] == [1, 2, 3, 4]
    assert q.try_pop() is EMPTY
    # wraparound
    for i in range(10):
        assert q.try_push(i)
        assert q.try_pop() == i


@given(st.lists(st.one_of(st.integers(0, 1000),
                          st.just("pop")), max_size=200),
       st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_model_based(ops, cap):
    """Queue behaves exactly like a bounded deque."""
    q = SpscQueue(cap)
    model = deque()
    for op in ops:
        if op == "pop":
            got = q.try_pop()
            if model:
                assert got == model.popleft()
            else:
                assert got is EMPTY
        else:
            ok = q.try_push(op)
            assert ok == (len(model) < cap)
            if ok:
                model.append(op)
    assert len(q) == len(model)


def test_threaded_stress():
    """1M items across a producer and a consumer thread, no locks."""
    q = SpscQueue(1024)
    N = 100_000
    out = []

    def producer():
        i = 0
        while i < N:
            if q.try_push(i):
                i += 1

    def consumer():
        while len(out) < N:
            item = q.try_pop()
            if item is not EMPTY:
                out.append(item)

    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start()
    tp.join(timeout=60); tc.join(timeout=60)
    assert out == list(range(N)), "FIFO order must survive concurrency"


def test_bidirectional_channel_roles():
    ch = BidirectionalChannel(8)
    assert ch.operation is ch.forward
    assert ch.activity is ch.backward
    ch.operation.try_push(("I", "P"))
    assert ch.operation.try_pop() == ("I", "P")


def test_channel_set_per_thread():
    cs = ChannelSet()
    chans = {}

    def worker(tid):
        chans[tid] = cs.channel_for(tid)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len({id(c) for c in chans.values()}) == 8
    # stable on re-request
    assert cs.channel_for(3) is chans[3]


def test_push_failure_counts():
    q = SpscQueue(1)
    q.try_push(1)
    q.try_push(2)
    q.try_push(3)
    assert q.push_failures == 2
    assert q.pushes == 1


# ---------------------------------------------------------------------------
# batch ops (ISSUE 1: amortize per-item Python overhead)
# ---------------------------------------------------------------------------
def test_push_many_pop_many_fifo():
    q = SpscQueue(8)
    assert q.try_push_many(list(range(5))) == 5
    assert q.try_push_many([5, 6, 7, 8, 9]) == 3, "only 3 slots left"
    assert q.try_pop_many() == [0, 1, 2, 3, 4, 5, 6, 7]
    assert q.try_pop_many() == []
    # wraparound across the ring boundary
    assert q.try_push_many([10, 11, 12, 13, 14, 15]) == 6
    assert q.try_pop_many(limit=2) == [10, 11]
    assert q.try_push_many([16, 17, 18, 19]) == 4
    assert q.try_pop_many() == [12, 13, 14, 15, 16, 17, 18, 19]


def test_push_many_full_and_counters():
    q = SpscQueue(2)
    assert q.try_push_many([1, 2, 3]) == 2
    assert q.push_failures == 1   # partial batch counts one failure
    assert q.try_push_many([4]) == 0
    assert q.push_failures == 2
    assert q.pushes == 2
    assert q.try_pop_many() == [1, 2]
    assert q.pops == 2


def test_batch_interleaves_with_scalar_ops():
    q = SpscQueue(16)
    q.try_push(0)
    q.try_push_many([1, 2, 3])
    q.try_push(4)
    assert q.try_pop() == 0
    assert q.try_pop_many(limit=3) == [1, 2, 3]
    assert q.try_pop() == 4


def test_batch_threaded_stress():
    """Producer pushes batches, consumer pops batches: FIFO survives."""
    q = SpscQueue(512)
    N = 50_000
    out = []

    def producer():
        i = 0
        while i < N:
            i += q.try_push_many(list(range(i, min(i + 64, N))))

    def consumer():
        while len(out) < N:
            out.extend(q.try_pop_many(128))

    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start()
    tp.join(timeout=60); tc.join(timeout=60)
    assert out == list(range(N))
