"""Regression tests for the §Perf levers: MoE weight modes, sLSTM time
blocking, microbatched training, fsdp plan, flash-VJP residual change."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_test_mesh
from repro.models import moe, transformer as T, xlstm
from repro.optim import adamw


def test_moe_stationary_matches_gather_and_local():
    mesh = make_test_mesh((1, 1))
    key = jax.random.PRNGKey(0)
    p = moe.init_moe_params(key, 32, 64, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.3
    y0, a0 = moe.moe_ffn(p, x, n_experts=4, top_k=2, capacity_factor=8.0,
                         mesh_args=None)
    with mesh:
        for mode in ("gather", "stationary"):
            args = moe.MoEMeshArgs(mesh, ("data",), "data", "model", mode)
            y, a = moe.moe_ffn(p, x, n_experts=4, top_k=2,
                               capacity_factor=8.0, mesh_args=args)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                       rtol=1e-5, atol=1e-5, err_msg=mode)
            assert float(a) == pytest.approx(float(a0), rel=1e-5)


@pytest.mark.parametrize("block", [1, 4, 16, 64])
def test_slstm_time_block_invariant(block):
    """Output must be identical for every time_block value."""
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    p = xlstm.init_slstm_params(ks[0], 64, 4, jnp.float32)
    x = jax.random.normal(ks[1], (2, 32, 64)) * 0.3
    y1, s1 = xlstm.slstm_forward(p, x, n_heads=4, time_block=1)
    yb, sb = xlstm.slstm_forward(p, x, n_heads=4, time_block=block)
    np.testing.assert_allclose(np.asarray(yb), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
    for k in s1:
        np.testing.assert_allclose(np.asarray(sb[k]), np.asarray(s1[k]),
                                   rtol=1e-5, atol=1e-5)


def test_slstm_non_divisible_block_falls_back():
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    p = xlstm.init_slstm_params(ks[0], 32, 2, jnp.float32)
    x = jax.random.normal(ks[1], (1, 12, 32)) * 0.3   # 12 % 16 != 0
    y, _ = xlstm.slstm_forward(p, x, n_heads=2, time_block=16)
    y1, _ = xlstm.slstm_forward(p, x, n_heads=2, time_block=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)


def test_microbatch_step_matches_full_batch():
    cfg = get_config("qwen2-1.5b").reduced()
    opts = T.ModelOptions(q_chunk=16, kv_chunk=16, loss_chunk=16)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                          0, cfg.vocab)}
    s1 = jax.jit(steps_mod.make_train_step(cfg, None, opts,
                                           adamw.OptConfig()))
    s2 = jax.jit(steps_mod.make_train_step(cfg, None, opts,
                                           adamw.OptConfig(),
                                           n_microbatches=2))
    p1, _, m1 = s1(params, adamw.init(params), batch)
    p2, _, m2 = s2(params, adamw.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)


def test_fsdp_plan_shards_params_fully():
    from repro.distributed import sharding as shard_mod
    mesh = make_test_mesh((1, 1))
    plan = shard_mod.make_plan(mesh, strategy="fsdp")
    assert plan.model_axis is None
    assert plan.dp_axes == ("data", "model")
    cfg = get_config("qwen2-1.5b").reduced()
    p = jax.eval_shape(lambda k: T.init_params(k, cfg),
                       jax.random.PRNGKey(0))
    sh = shard_mod.param_shardings(p, cfg, plan)
    # on a 1x1 mesh everything divides: every leaf must carry a spec tree
    for s in jax.tree.leaves(sh):
        assert s.mesh is mesh or s.mesh == mesh


def test_flash_vjp_qkv_residuals_grad_correct():
    """After the A5 residual change, flash grads still match the oracle."""
    from repro.models.attention import chunked_attention
    from repro.kernels.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    g1 = jax.grad(lambda *a: (chunked_attention(
        *a, q_chunk=32, kv_chunk=32) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (attention_ref(
        *a, causal=True) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
