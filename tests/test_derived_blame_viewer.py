"""Derived metrics (§4.5/§7.1), idleness blame (§7.2/§8.5), viewer (§7)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.blame import blame_gpu_idleness, blame_report
from repro.core.derived import (DerivedMetric, GPU_UTILIZATION, SYNC_DIFF,
                                WARP_ISSUE_RATE, sanitize)
from repro.core.trace import TraceData


# ---------------------------------------------------------------------------
# derived metrics
# ---------------------------------------------------------------------------
def test_formula_basics():
    m = DerivedMetric("d", "a / (a + b)")
    out = m.evaluate({"a": np.array([1.0, 2.0]), "b": np.array([1.0, 2.0])})
    np.testing.assert_allclose(out, [0.5, 0.5])


def test_divide_by_zero_yields_zero():
    m = DerivedMetric("d", "a / b")
    out = m.evaluate({"a": np.array([1.0]), "b": np.array([0.0])})
    np.testing.assert_allclose(out, [0.0])


def test_paper_formulas():
    cols = {
        "gpu_inst/samples": np.array([80.0]),
        "gpu_inst/stall_compute": np.array([10.0]),
        "gpu_inst/stall_memory": np.array([10.0]),
        "gpu_inst/stall_collective": np.array([0.0]),
        "gpu_sync/invocations": np.array([5.0]),
        "gpu_kernel/invocations": np.array([3.0]),
        "gpu_kernel/time_ns": np.array([300.0]),
        "cpu/time_ns": np.array([700.0]),
    }
    assert WARP_ISSUE_RATE.evaluate(cols)[0] == pytest.approx(0.8)
    assert SYNC_DIFF.evaluate(cols)[0] == pytest.approx(2.0)
    assert GPU_UTILIZATION.evaluate(cols)[0] == pytest.approx(0.3)


@pytest.mark.parametrize("bad", [
    "__import__('os')", "a.b", "lambda: 1", "[1,2]", "open('x')",
    "exec('x')", "a if (x := 3) else b",
])
def test_formula_rejects_unsafe(bad):
    with pytest.raises((ValueError, SyntaxError)):
        DerivedMetric("bad", bad)


def test_whitelisted_funcs_and_compare():
    m = DerivedMetric("d", "where(a > b, sqrt(a), max(a, b))")
    out = m.evaluate({"a": np.array([4.0, 1.0]), "b": np.array([1.0, 9.0])})
    np.testing.assert_allclose(out, [2.0, 9.0])


@given(st.lists(st.floats(0.1, 100), min_size=1, max_size=8),
       st.lists(st.floats(0.1, 100), min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_formula_matches_numpy(a, b):
    n = min(len(a), len(b))
    a, b = np.array(a[:n]), np.array(b[:n])
    m = DerivedMetric("d", "(a * 2 + b) / (a + b) - a ** 0.5")
    np.testing.assert_allclose(m.evaluate({"a": a, "b": b}),
                               (a * 2 + b) / (a + b) - a ** 0.5)


# ---------------------------------------------------------------------------
# blame analysis
# ---------------------------------------------------------------------------
def tr(ident, records):
    arr = np.asarray(records, np.int64).reshape(-1, 3)
    return TraceData(ident, arr[:, 0], arr[:, 1], arr[:, 2])


def test_blame_simple():
    # GPU busy [0, 10); idle [10, 30) while cpu ctx 7 active;
    gpu = [tr({"stream": 0}, [(0, 10, 1)])]
    cpu = [tr({"thread": 0}, [(0, 30, 7)])]
    blame, idle = blame_gpu_idleness(cpu, gpu)
    assert idle == 20
    assert blame == {7: 20.0}


def test_blame_split_across_threads():
    gpu = [tr({"stream": 0}, [(0, 10, 1)])]
    cpu = [tr({"thread": 0}, [(0, 30, 7)]),
           tr({"thread": 1}, [(10, 20, 8)])]
    blame, idle = blame_gpu_idleness(cpu, gpu)
    assert idle == 20
    # [10,20): both active -> 5 each; [20,30): only ctx7 -> 10
    assert blame[7] == pytest.approx(15.0)
    assert blame[8] == pytest.approx(5.0)


def test_blame_no_idle_when_any_stream_busy():
    gpu = [tr({"stream": 0}, [(0, 10, 1)]),
           tr({"stream": 1}, [(5, 30, 2)])]
    cpu = [tr({"thread": 0}, [(0, 30, 7)])]
    blame, idle = blame_gpu_idleness(cpu, gpu)
    assert idle == 0
    assert blame == {}


def test_blame_report_ranks(tmp_path):
    from repro.core.aggregate import aggregate
    from tests.test_aggregate import write_rank_profiles
    paths, _ = write_rank_profiles(tmp_path)
    db = aggregate(paths, str(tmp_path / "db"), n_ranks=1, n_threads=1)
    blame = {1: 60.0, 2: 40.0}
    rows = blame_report(blame, 100.0, db)
    assert rows[0][1] == pytest.approx(0.6)
    assert rows[0][1] >= rows[1][1]


# ---------------------------------------------------------------------------
# viewer
# ---------------------------------------------------------------------------
def test_viewer_views(tmp_path):
    from repro.core.aggregate import aggregate
    from repro.core.sparse import CMSReader
    from repro.core import viewer
    from tests.test_aggregate import write_rank_profiles
    paths, _ = write_rank_profiles(tmp_path)
    db = aggregate(paths, str(tmp_path / "db"), n_ranks=2, n_threads=2)

    td = viewer.top_down(db, "gpu_kernel/time_ns")
    assert "TOP-DOWN" in td and "kernel:train" in td
    fl = viewer.flat(db, "gpu_kernel/time_ns")
    assert "FLAT" in fl and "%" in fl
    bu = viewer.bottom_up(db, "gpu_kernel/time_ns")
    assert "BOTTOM-UP" in bu
    # thread-centric plot
    cms = CMSReader(db.cms_path())
    ph = [i for i, f in enumerate(db.frames) if f.kind == "placeholder"][0]
    pids, vals = viewer.thread_plot(db, cms, ph, "gpu_kernel/time_ns")
    assert len(pids) == 6 and sorted(vals)[0] == 100.0


def test_trace_statistic(tmp_path):
    from repro.core.aggregate import aggregate
    from repro.core import viewer
    from repro.core.trace import read_trace
    import os
    from tests.test_aggregate import write_rank_profiles
    paths, _ = write_rank_profiles(tmp_path)
    traces = [p.replace(".rpro", ".rtrc") for p in paths]
    out = str(tmp_path / "db")
    db = aggregate(paths, out, n_ranks=1, n_threads=1, trace_paths=traces)
    tds = [read_trace(os.path.join(out, os.path.basename(t)))
           for t in traces]
    rows = viewer.trace_statistic(tds, db, depth=1)
    assert rows and abs(sum(v for _, v in rows) - 1.0) < 1e-6
