"""Streaming aggregation (paper §6.1): unification, expansion, statistics,
sparse outputs, trace conversion."""
import os

import numpy as np
import pytest

from repro.core.aggregate import Database, GlobalTree, aggregate
from repro.core.cct import CCT, Frame, GPU_OP, HOST, PLACEHOLDER
from repro.core.metrics import default_registry
from repro.core.profmt import write_profile
from repro.core.sparse import CMSReader, PMSReader
from repro.core.trace import TraceWriter, read_trace


def write_rank_profiles(tmp_path, n=6):
    """n profiles sharing structure: root -> main -> {step: kernel}."""
    reg = default_registry()
    paths = []
    for r in range(n):
        cct = CCT()
        main = cct.insert_path([Frame(HOST, "main", "app.py", 1)])
        step = cct.insert_path([Frame(HOST, "step", "app.py", 10)],
                               parent=main)
        ph = cct.get_or_insert(step, Frame(PLACEHOLDER, "kernel:train", "0",
                                           0))
        ph.metrics.add(reg.kind("gpu_kernel"), "invocations", 1 + r)
        ph.metrics.add(reg.kind("gpu_kernel"), "time_ns", 100.0 * (r + 1))
        main.metrics.add(reg.kind("cpu"), "time_ns", 1000.0)
        p = str(tmp_path / f"profile_r{r}_t0.rpro")
        write_profile(p, cct, reg, {"rank": r, "thread": 0, "type": "cpu"},
                      [])
        # a trace aligned with the profile
        tw = TraceWriter(p.replace(".rpro", ".rtrc"), {"rank": r})
        tw.append(0, 50, step.node_id)
        tw.append(50, 80, ph.node_id)
        tw.close()
        paths.append(p)
    return paths, reg


@pytest.mark.parametrize("n_ranks,n_threads", [(1, 1), (3, 2), (4, 4)])
def test_aggregate_stats(tmp_path, n_ranks, n_threads):
    paths, reg = write_rank_profiles(tmp_path)
    db = aggregate(paths, str(tmp_path / f"db{n_ranks}_{n_threads}"),
                   n_ranks=n_ranks, n_threads=n_threads)
    mid = db.metric_id("gpu_kernel/invocations")
    # find the placeholder context
    ph = [i for i, f in enumerate(db.frames) if f.kind == PLACEHOLDER]
    assert len(ph) == 1, "same call path must unify into one global node"
    i = ph[0]
    assert db.stats["sum"][i, mid] == pytest.approx(sum(range(1, 7)))
    assert db.stats["min"][i, mid] == 1
    assert db.stats["max"][i, mid] == 6
    assert db.stats["mean"][i, mid] == pytest.approx(3.5)
    std = np.std(np.arange(1, 7))
    assert db.stats["std"][i, mid] == pytest.approx(std, rel=1e-6)
    assert db.stats["cov"][i, mid] == pytest.approx(std / 3.5, rel=1e-6)


def test_inclusive_propagation(tmp_path):
    """Metrics flow up to ancestors (inclusive view)."""
    paths, reg = write_rank_profiles(tmp_path)
    db = aggregate(paths, str(tmp_path / "db"), n_ranks=2, n_threads=2)
    tmid = db.metric_id("gpu_kernel/time_ns")
    root_val = db.stats["sum"][0, tmid]
    assert root_val == pytest.approx(sum(100.0 * (r + 1) for r in range(6)))


def test_sparse_cube_outputs(tmp_path):
    paths, reg = write_rank_profiles(tmp_path)
    out = str(tmp_path / "db")
    db = aggregate(paths, out, n_ranks=2, n_threads=2)
    cms = CMSReader(db.cms_path())
    pms = PMSReader(db.pms_path())
    mid = db.metric_id("gpu_kernel/invocations")
    ph = [i for i, f in enumerate(db.frames) if f.kind == PLACEHOLDER][0]
    pids, vals = cms.metric_values(ph, mid)
    assert sorted(vals) == [1, 2, 3, 4, 5, 6]
    for p, v in zip(pids, vals):
        assert pms.context_values(int(p), ph)[mid] == v


def test_trace_conversion(tmp_path):
    paths, reg = write_rank_profiles(tmp_path)
    traces = [p.replace(".rpro", ".rtrc") for p in paths]
    out = str(tmp_path / "db")
    db = aggregate(paths, out, n_ranks=2, n_threads=2, trace_paths=traces)
    td = read_trace(os.path.join(out, os.path.basename(traces[0])))
    # converted ctx ids must be valid global ids
    assert all(0 <= c < len(db.frames) for c in td.ctx)
    names = {db.frames[int(c)].name for c in td.ctx}
    assert "step" in names and "kernel:train" in names


def test_database_load_roundtrip(tmp_path):
    paths, _ = write_rank_profiles(tmp_path)
    out = str(tmp_path / "db")
    db = aggregate(paths, out, n_ranks=1, n_threads=2)
    db2 = Database.load(out)
    assert db2.metrics == db.metrics
    assert len(db2.frames) == len(db.frames)
    np.testing.assert_allclose(db2.stats["sum"], db.stats["sum"])


def test_expansion_against_structure(tmp_path):
    """Phase 3: flat GPU_OP frames expand into scope/loop/op chains."""
    import jax
    import jax.numpy as jnp
    from repro.core.structure import parse_hlo
    from repro.core.aggregate import make_expander

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    hlo = jax.jit(f).lower(jnp.ones((16, 16))).compile().as_text()
    mod = parse_hlo(hlo, name="f")
    reg = default_registry()
    cct = CCT()
    ph = cct.insert_path([Frame(HOST, "main", "app.py", 1),
                          Frame(PLACEHOLDER, "kernel:f", "0", 0)])
    ops = mod.all_ops()
    dot = next(i for i, o in enumerate(ops) if o.opcode == "dot")
    gnode = cct.insert_path([Frame(GPU_OP, "dot", "f", dot)], parent=ph)
    gnode.metrics.add(reg.kind("gpu_inst"), "samples", 7)
    p = str(tmp_path / "p.rpro")
    write_profile(p, cct, reg, {"rank": 0}, ["f"])
    db = aggregate([p], str(tmp_path / "db"), n_ranks=1, n_threads=1,
                   structures={"f": mod})
    kinds = {f.kind for f in db.frames}
    assert "gpu_op" in kinds
    sampled = [i for i, f in enumerate(db.frames) if f.kind == "gpu_op"]
    mid = db.metric_id("gpu_inst/samples")
    assert db.stats["sum"][sampled, mid].sum() == 7


def test_merge_tree_mapping():
    t1, t2 = GlobalTree(), GlobalTree()
    a1 = t1.child(0, Frame(HOST, "a", "", 0))
    a2 = t2.child(0, Frame(HOST, "a", "", 0))
    b2 = t2.child(a2, Frame(HOST, "b", "", 0))
    mapping = t1.merge_tree(t2)
    assert mapping[a2] == a1
    assert t1.frames[int(mapping[b2])].name == "b"
