"""Examples smoke suite (ISSUE 4 satellite).

Every ``examples/*.py`` is product surface the docs point at, but none
were executed by the test suite, so they could rot silently (import
drift, API renames, stale kwargs).  This runs each one as a subprocess
in a scratch cwd and asserts exit 0 — nothing about their output, just
that they still run end to end.  New examples are picked up
automatically by the glob.

The jax-heavy examples dominate suite wall-clock; they run here with the
same defaults a user gets, so a pass means the documented command line
works verbatim.
"""
import glob
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = sorted(glob.glob(os.path.join(REPO, "examples", "*.py")))

# per-example extra argv: keep the smoke cheap where the example exposes
# size knobs (defaults unchanged for users; asserted to stay valid flags)
EXTRA_ARGS = {
    "serve_batch.py": ["--requests", "2", "--gen-len", "4"],
    # defaults train 30 steps (~10 min on a 1-core box); 4 steps walks the
    # identical pipeline (train, checkpoint, profile, aggregate, views)
    "profile_train.py": ["--steps", "4", "--seq", "64", "--batch", "2"],
}


def test_every_example_is_collected():
    names = {os.path.basename(p) for p in EXAMPLES}
    assert "quickstart.py" in names
    assert "continuous_profiling.py" in names, \
        "ISSUE 4 demo must exist and be smoked"
    assert "parallel_aggregate.py" in names, \
        "ISSUE 5 demo must exist and be smoked"
    assert len(EXAMPLES) >= 10


@pytest.mark.parametrize(
    "path", EXAMPLES, ids=[os.path.basename(p) for p in EXAMPLES])
def test_example_runs_clean(path, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # examples write through tempfile.mkdtemp(); point TMPDIR at the test
    # sandbox so everything they produce is cleaned up with it
    env["TMPDIR"] = str(tmp_path)
    args = EXTRA_ARGS.get(os.path.basename(path), [])
    proc = subprocess.run([sys.executable, path, *args], cwd=str(tmp_path),
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, (
        f"{os.path.basename(path)} exited {proc.returncode}\n"
        f"--- stdout (tail) ---\n{proc.stdout[-2000:]}\n"
        f"--- stderr (tail) ---\n{proc.stderr[-2000:]}")
