"""Checkpoint manager (atomic/async/sharded/elastic) + fault tolerance."""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.ft import (RestartPolicy, StragglerWatchdog, plan_elastic_mesh)


def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "opt": {"step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, tree())
    step, restored = mgr.restore(tree())
    assert step == 10
    np.testing.assert_allclose(restored["params"]["w"],
                               np.arange(12.0).reshape(3, 4))
    assert int(restored["opt"]["step"]) == 7


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree(), block=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_atomicity_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, tree())
    # a stale tmp dir (crashed writer) must be invisible to restore
    os.makedirs(str(tmp_path / "step_00000009.tmp"))
    assert mgr.latest_step() == 3
    step, _ = mgr.restore(tree())
    assert step == 3


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree())
    assert mgr.all_steps() == [3, 4]


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=0)
    t = tree()
    mgr.save(1, t)
    t2 = {"params": {"w": t["params"]["w"] * 2, "b": t["params"]["b"]},
          "opt": {"step": jnp.int32(8)}}
    mgr.save(2, t2)
    step, restored = mgr.restore(tree(), step=1)
    np.testing.assert_allclose(restored["params"]["w"],
                               np.arange(12.0).reshape(3, 4))


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

d = %r
from repro.launch.mesh import make_mesh
mesh8 = make_mesh((8,), ("data",))
sh8 = NamedSharding(mesh8, P("data"))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh8)
mgr = CheckpointManager(d)
mgr.save(5, {"x": x})
assert len(x.addressable_shards) == 8

# elastic restore onto a DIFFERENT mesh shape (2 x 4, sharded both dims)
mesh24 = make_mesh((2, 4), ("a", "b"))
sh24 = NamedSharding(mesh24, P("a", "b"))
step, out = mgr.restore({"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                        shardings={"x": sh24})
assert step == 5
np.testing.assert_allclose(np.asarray(out["x"]),
                           np.arange(64.0).reshape(8, 8))
assert out["x"].sharding == sh24
print("ELASTIC_OK")
"""


def test_elastic_restore_across_meshes(tmp_path):
    """Save on an (8,) mesh, restore onto (2,4) — different sharding."""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT % str(tmp_path / "ck")],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300)
    assert "ELASTIC_OK" in proc.stdout, proc.stderr[-2000:]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_watchdog_stale_host():
    t = [0.0]
    wd = StragglerWatchdog(stale_s=10, lag_steps=5, clock=lambda: t[0])
    for h in ("h0", "h1", "h2"):
        wd.beat(h, 1)
    t[0] = 20.0
    wd.beat("h0", 2)
    wd.beat("h1", 2)
    assert wd.stragglers() == ["h2"]


def test_watchdog_lagging_host():
    t = [0.0]
    wd = StragglerWatchdog(stale_s=1e9, lag_steps=5, clock=lambda: t[0])
    for step in range(12):
        t[0] += 1
        wd.beat("h0", step)
        wd.beat("h1", step)
        wd.beat("h2", step // 4)  # lags
    assert "h2" in wd.stragglers()


def test_watchdog_slow_hosts():
    t = [0.0]
    wd = StragglerWatchdog(clock=lambda: t[0])
    for step in range(10):
        for h, dt in (("h0", 1.0), ("h1", 1.0), ("h2", 3.0)):
            wd.beat(h, step, t=step * dt)
    assert wd.slow_hosts(factor=1.5) == ["h2"]


def test_restart_policy_budget_and_backoff():
    rp = RestartPolicy(max_restarts=3, window_s=100, backoff_base_s=5,
                       backoff_max_s=40)
    for i in range(3):
        rp.record_failure(float(i))
        assert rp.should_restart(float(i))
    assert rp.backoff_s() == 20  # 5 * 2**2
    rp.record_failure(3.0)
    assert not rp.should_restart(3.5)
    # outside the window the budget refills
    assert rp.should_restart(1000.0)
    for _ in range(5):
        rp.record_failure(1000.0)
    assert rp.backoff_s() == 40  # capped


def test_elastic_plan_shrinks_data_axis():
    p = plan_elastic_mesh(256 - 16, model=16, old_data=16)
    assert p.mesh_shape == (8, 16)
    assert p.global_batch_scale == pytest.approx(0.5)


def test_elastic_plan_multipod_collapse():
    # half a pod dies: 2x16x16=512 -> 384 devices; pods collapse to 1
    p = plan_elastic_mesh(384, model=16, pods=2, old_data=16)
    assert p.mesh_shape[-1] == 16
    total = int(np.prod(p.mesh_shape))
    assert total <= 384
    assert p.mesh_axes[-1] == "model"


def test_elastic_plan_keeps_tp():
    with pytest.raises(AssertionError):
        plan_elastic_mesh(8, model=16)
