"""Data pipeline, optimizer, gradient compression, sharding plan units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed import compression as comp
from repro.distributed import sharding as shard_mod
from repro.optim import adamw


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_batch_deterministic():
    cfg = get_config("qwen2-1.5b").reduced()
    shape = ShapeConfig("t", 32, 4, "train")
    ds = SyntheticLM(cfg, shape, seed=1)
    a = ds.batch_at(7)
    b = ds.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_sharding_disjoint():
    cfg = get_config("qwen2-1.5b").reduced()
    shape = ShapeConfig("t", 16, 8, "train")
    h0 = SyntheticLM(cfg, shape, seed=1, n_hosts=2, host_id=0).batch_at(3)
    h1 = SyntheticLM(cfg, shape, seed=1, n_hosts=2, host_id=1).batch_at(3)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_labels_are_next_token():
    cfg = get_config("qwen2-1.5b").reduced()
    ds = SyntheticLM(cfg, ShapeConfig("t", 16, 2, "train"), seed=0)
    b = ds.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_ordered():
    cfg = get_config("qwen2-1.5b").reduced()
    ds = SyntheticLM(cfg, ShapeConfig("t", 8, 2, "train"), seed=0)
    pf = Prefetcher(ds, start_step=5)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_vlm_batch_masks_frontend_labels():
    cfg = get_config("llava-next-mistral-7b").reduced()
    ds = SyntheticLM(cfg, ShapeConfig("t", 16, 2, "train"), seed=0)
    b = ds.batch_at(0)
    F = b["embeds"].shape[1]
    assert (b["labels"][:, :F] == -100).all()


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_decreases_quadratic():
    cfg = adamw.OptConfig(peak_lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = adamw.init(params)

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(
            lambda p_: jnp.sum(p_["x"] ** 2))(p)
        p2, s2, m = adamw.update(cfg, g, s, p)
        return p2, s2, loss

    losses = []
    for _ in range(50):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < 0.1 * losses[0]


def test_grad_clipping_reported_norm():
    cfg = adamw.OptConfig(clip_norm=1.0, warmup_steps=0)
    params = {"x": jnp.zeros(3)}
    state = adamw.init(params)
    huge = {"x": jnp.full(3, 1e6)}
    p1, _, m = adamw.update(cfg, huge, state, params)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip norm
    # clipped update: same step as a grad of global-norm 1 in that direction
    unit = {"x": jnp.full(3, 1.0 / np.sqrt(3.0))}
    p2, _, _ = adamw.update(cfg, unit, adamw.init(params), params)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]),
                               rtol=1e-5, atol=1e-7)


def test_schedule_warmup_and_decay():
    cfg = adamw.OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.int32(0)))
    lr5 = float(adamw.schedule(cfg, jnp.int32(5)))
    lr10 = float(adamw.schedule(cfg, jnp.int32(10)))
    lr100 = float(adamw.schedule(cfg, jnp.int32(100)))
    assert lr0 == 0.0 and lr5 == pytest.approx(0.5)
    assert lr10 == pytest.approx(1.0)
    assert lr100 == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_small():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    q, scale = comp.quantize(g)
    back = comp.dequantize(q, scale, g.shape, g.dtype)
    err = np.abs(np.asarray(back - g))
    # per-block bound: |err| <= scale/2 per element
    bound = np.repeat(np.asarray(scale), comp.BLOCK)[:g.size].reshape(
        g.shape) / 2 + 1e-6
    assert (err <= bound).all()


def test_error_feedback_converges():
    """EF compensation: mean of compressed grads -> true grad."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((512,)), jnp.float32)
    ef = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(200):
        out, ef = comp.ef_compress(g, ef)
        acc = acc + out
    np.testing.assert_allclose(np.asarray(acc / 200), np.asarray(g),
                               atol=0.02)


def test_compressed_psum_matches_plain():
    try:
        from jax import shard_map
    except ImportError:   # moved out of experimental in newer jax
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1, 1))
    x = jnp.asarray(np.random.default_rng(2).standard_normal((2, 256)),
                    jnp.float32)
    f = shard_map(lambda v: comp.compressed_psum(v, "data"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x), rtol=2e-2,
                               atol=2e-2)


def test_ef_compress_tree_shapes():
    tree = {"a": jnp.ones((8, 8)), "b": {"c": jnp.ones((4,))}}
    out = comp.ef_compress_tree(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype


# ---------------------------------------------------------------------------
# sharding plan
# ---------------------------------------------------------------------------
def test_plan_on_trivial_mesh():
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh()
    plan = shard_mod.make_plan(mesh)
    assert plan.model_axis == "model"
    assert plan.batch_spec() == jax.sharding.PartitionSpec(("data",))


def test_param_specs_divisibility_guard():
    """Non-divisible dims fall back to replication (explicit in_shardings
    must divide exactly)."""
    from repro.launch.mesh import make_test_mesh
    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_test_mesh()
    plan = shard_mod.make_plan(mesh)
    from repro.models import transformer as T
    p = jax.eval_shape(lambda k: T.init_params(k, cfg),
                       jax.random.PRNGKey(0))
    sh = shard_mod.param_shardings(p, cfg, plan)
    for leaf, s in zip(jax.tree.leaves(p), jax.tree.leaves(sh)):
        for dim, names in zip(leaf.shape, s.spec + (None,) * 4):
            if names is None:
                continue
            n = np.prod([mesh.shape[a] for a in
                         (names if isinstance(names, tuple) else (names,))])
            assert dim % n == 0


def test_dp_only_strategy_replicates():
    from repro.launch.mesh import make_test_mesh
    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_test_mesh()
    plan = shard_mod.make_plan(mesh, strategy="dp_only")
    from repro.models import transformer as T
    p = jax.eval_shape(lambda k: T.init_params(k, cfg),
                       jax.random.PRNGKey(0))
    sh = shard_mod.param_shardings(p, cfg, plan)
    for s in jax.tree.leaves(sh):
        # P() and P(None, ..., None) are the same sharding
        assert all(ax is None for ax in s.spec)
