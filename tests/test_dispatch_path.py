"""ISSUE 10: the wait-free dispatch path.

Pins the tentpole contracts end to end: per-thread record rings lose or
duplicate nothing under concurrent dispatch and preserve per-thread FIFO
order; the deferred PC-sample draw is a pure function of the dispatch
identity (seed, thread lane, seq) — invariant under monitor drain order
and batch splits; multi-threaded runs with bound thread indices produce
byte-identical canonical databases, and the exactly-once spine (one-shot
aggregate == shards + merge_databases) holds unchanged; and the
overhead-counter snapshot is internally consistent under a concurrent
reader hammer (the satellite (a) read-vs-update race).

Also pins ``KeyedRng``'s in-place state-swap against fresh
``Generator(Philox(key))`` construction (the optimization's correctness
claim in ``repro.core.sampling``) and ``DispatchStream``'s counter-hash
stream determinism.
"""
import threading

import numpy as np
import pytest

from repro.core import sampling
from repro.core.aggregate import aggregate
from repro.core.merge import merge_databases
from repro.core.profiler import Profiler
from repro.core.sampling import KeyedRng, _SMALL_DRAW

from test_kstruct import KERNEL_HLO, bound_module, hand_structure
from test_merge import assert_db_identical, db_bytes


class ThreadClock:
    """Deterministic per-thread clock: thread ``i`` (after ``bind(i)``)
    returns ``i << 44`` plus a fixed step per call, so every timestamp
    is a pure function of the calling thread's own call count —
    scheduling-invariant — and no two threads' timestamps ever collide
    (distinct bases)."""

    def __init__(self, step=1000):
        self._local = threading.local()
        self.step = step

    def bind(self, index):
        self._local.base = int(index) << 44
        self._local.n = 0

    def __call__(self):
        loc = self._local
        n = loc.n = getattr(loc, "n", 0) + 1
        return getattr(loc, "base", 0) + n * self.step


def _run_threads(n, target):
    barrier = threading.Barrier(n)
    errors = []

    def body(i):
        try:
            barrier.wait()
            target(i)
        except Exception as e:             # surface, don't hang the join
            errors.append(e)

    threads = [threading.Thread(target=body, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    return errors


# ---------------------------------------------------------------------------
# concurrent dispatch stress: nothing lost, nothing duplicated, FIFO
# ---------------------------------------------------------------------------
def test_concurrent_dispatch_stress(tmp_path):
    """8 threads x 10k dispatches of a randomized kernel mix (including
    a kstruct-bound module and budgets above ``_SMALL_DRAW``, so both
    draw paths run).  Every dispatch must surface exactly once — in the
    monitor stats, the overhead counters, and the per-thread trace
    chunks — and each thread's trace rows must be in its dispatch
    (FIFO) order."""
    n_threads, n_disp = 8, 10_000
    clock = ThreadClock(step=1000)
    prof = Profiler(str(tmp_path / "run"), tracing=True, rng_seed=0,
                    sample_rate_hz=1e6, clock=clock, unwind=False)
    mid = prof.register_module("flash", KERNEL_HLO)
    assert prof.register_kernel_structures(mid, [hand_structure()]) == 1
    # duration_ns overrides -> deterministic budgets: 1 (floor), 7
    # (small-draw categorical), 100 (> _SMALL_DRAW: lazy Philox path)
    mix = [("kernel", "flash", mid, 100_000),
           ("kernel", "flash", mid, 7_000),
           ("kernel", "k0", None, 1_000),
           ("copy", "h2d", None, 2_000),
           ("sync", "s", None, 1_000)]

    def worker(i):
        prof.bind_thread(i)
        clock.bind(i)
        rng = np.random.default_rng(i)
        picks = rng.integers(0, len(mix), size=n_disp)
        for j in range(n_disp):
            kind, name, m, dur = mix[picks[j]]
            with prof.dispatch(kind, name, stream=0, module_id=m,
                               duration_ns=dur):
                pass

    with prof:
        _run_threads(n_threads, worker)
        assert prof.flush(timeout=60.0)

    total = n_threads * n_disp
    stats = prof._monitor.stats
    assert stats["ops"] == total            # every OP record drained
    assert stats["activities"] == total     # every ACTIVITY record drained
    assert stats["routed"] == total         # every activity trace-routed
    c = prof.overhead_counters()
    assert c["dispatches"] == total
    assert c["samples_kept"] > 0
    # ring accounting closes: appends == reads (OP + ACTIVITY per dispatch)
    rings = prof._rings.items()
    assert sum(r.appends for _, r in rings) == 2 * total
    assert sum(r.reads for _, r in rings) == 2 * total
    # per-thread FIFO: each thread's trace chunks concatenate to exactly
    # n_disp rows with strictly increasing starts (the deterministic
    # clock makes any reorder, loss, or duplicate a visible violation)
    for st in prof._threads.values():
        lane = np.concatenate([np.asarray(ch) for ch in st.trace_chunks])
        assert lane.shape == (n_disp, 3)
        starts = lane[:, 0]
        assert (np.diff(starts) > 0).all()


# ---------------------------------------------------------------------------
# byte-determinism: bound lanes, deterministic clocks, keyed draws
# ---------------------------------------------------------------------------
def _mt_run(out_dir, *, rank=0, n_threads=4, n_disp=150, batch=None):
    """A deterministic multi-threaded run: each worker binds its thread
    index, gets its own clock lane, and dispatches a module-bound kernel
    mix on its own stream."""
    clock = ThreadClock(step=1000)
    prof = Profiler(str(out_dir), tracing=True, rng_seed=0, rank=rank,
                    sample_rate_hz=1e6, clock=clock, unwind=False)
    if batch is not None:
        prof._monitor._batch = batch
    mid = prof.register_module("flash", KERNEL_HLO)
    prof.register_kernel_structures(mid, [hand_structure()])

    def worker(i):
        prof.bind_thread(i)
        clock.bind(i)
        for j in range(n_disp):
            dur = (1_000, 7_000, 100_000)[(i + j) % 3]
            with prof.dispatch("kernel", "flash", stream=i, module_id=mid,
                               duration_ns=dur):
                pass
            with prof.dispatch("copy", "h2d", stream=i, nbytes=1 << 20,
                               duration_ns=2_000):
                pass

    with prof:
        _run_threads(n_threads, worker)
        assert prof.flush(timeout=60.0)
        paths = prof.write()
    profs = [p for k, p in sorted(paths.items()) if "trace" not in k]
    traces = [p for k, p in sorted(paths.items()) if "trace" in k]
    return profs, traces


def test_multithreaded_runs_byte_identical(tmp_path):
    """Five repeats of the same bound-lane multi-threaded workload
    aggregate to byte-identical canonical databases: thread scheduling,
    ring interleaving, and monitor drain timing must leave no residue in
    the database bytes (the acceptance pin for satellite (c))."""
    want = None
    for rep in range(5):
        profs, traces = _mt_run(tmp_path / f"run{rep}")
        db = str(tmp_path / f"db{rep}")
        aggregate(profs, db, trace_paths=traces)
        got = db_bytes(db)
        if want is None:
            want = got
        else:
            for fn, blob in want.items():
                assert got[fn] == blob, f"{fn} diverged on repeat {rep}"


def test_drain_order_and_batch_split_invariance(tmp_path):
    """The deferred draw + batched trace appends must be invariant to
    how the monitor happens to slice the rings: a tiny drain batch
    (many chunks, interleaved with dispatch) and the default batch
    produce byte-identical databases."""
    a_profs, a_traces = _mt_run(tmp_path / "a", n_threads=2, batch=None)
    b_profs, b_traces = _mt_run(tmp_path / "b", n_threads=2, batch=3)
    da, db_ = str(tmp_path / "dba"), str(tmp_path / "dbb")
    aggregate(a_profs, da, trace_paths=a_traces)
    aggregate(b_profs, db_, trace_paths=b_traces)
    assert_db_identical(db_, da)


def test_multithreaded_aggregate_equals_shards_plus_merge(tmp_path):
    """The exactly-once spine holds over the wait-free path: a one-shot
    aggregate of two multi-threaded ranks is byte-identical to per-rank
    shard aggregation + merge_databases, in either shard order."""
    runs = [_mt_run(tmp_path / f"rank{r}", rank=r, n_threads=2)
            for r in range(2)]
    one = str(tmp_path / "one")
    aggregate([p for ps, _ in runs for p in ps], one,
              trace_paths=[t for _, ts in runs for t in ts])
    shards = []
    for i, (ps, ts) in enumerate(runs):
        d = str(tmp_path / f"shard{i}")
        aggregate(ps, d, trace_paths=ts)
        shards.append(d)
    merged = str(tmp_path / "merged")
    merge_databases(shards, merged)
    assert_db_identical(merged, one)
    again = str(tmp_path / "again")
    merge_databases(list(reversed(shards)), again)
    assert db_bytes(again) == db_bytes(merged)


# ---------------------------------------------------------------------------
# satellite (a): consistent overhead-counter snapshots under load
# ---------------------------------------------------------------------------
def test_overhead_counters_consistent_under_hammer(tmp_path):
    """4 dispatching threads with a deterministic clock make the
    per-thread counters obey exact linear invariants (tool == 2 * app,
    app == step * dispatches); concurrent reader threads hammer
    ``overhead_counters()`` and every snapshot must satisfy them.  The
    pre-fix dict-increment path tore (tool updated, dispatches not);
    the single-tuple publish cannot."""
    step = 250
    clock = ThreadClock(step=step)
    prof = Profiler(str(tmp_path / "run"), tracing=False, clock=clock,
                    unwind=False)
    n_threads, n_disp = 4, 4000
    done = threading.Event()
    violations = []

    def reader():
        while not done.is_set():
            c = prof.overhead_counters()
            if c["tool_ns"] != 2 * c["app_ns"] or \
                    c["app_ns"] != step * c["dispatches"]:
                violations.append(dict(c))

    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in readers:
        t.start()

    def worker(i):
        prof.bind_thread(i)
        clock.bind(i)
        for _ in range(n_disp):
            with prof.dispatch("kernel", "k", stream=0):
                pass

    with prof:
        try:
            _run_threads(n_threads, worker)
        finally:
            done.set()
            for t in readers:
                t.join()
    assert not violations, violations[:3]
    c = prof.overhead_counters()
    assert c["dispatches"] == n_threads * n_disp
    assert c["tool_ns"] == 2 * c["app_ns"]
    assert c["app_ns"] == step * c["dispatches"]


def test_bind_thread_contract(tmp_path):
    prof = Profiler(str(tmp_path / "run"), tracing=False)
    prof.bind_thread(3)
    with pytest.raises(ValueError):
        prof.bind_thread(-1)
    results = {}

    def other():
        try:
            prof.bind_thread(3)          # already taken by main thread
        except ValueError as e:
            results["err"] = e

    t = threading.Thread(target=other)
    t.start()
    t.join()
    assert "err" in results
    # binding after the first dispatch is an error (the lane already
    # keyed draws and trace rows)
    with prof:
        with prof.dispatch("kernel", "k", stream=0):
            pass
        with pytest.raises(RuntimeError):
            prof.bind_thread(7)


# ---------------------------------------------------------------------------
# KeyedRng: the state-swap pin and drain-order-invariant draws
# ---------------------------------------------------------------------------
def _philox_key(seed, lane, seq):
    # explicit uint64: a plain int list goes through an int64 cast in
    # numpy and mangles keys above 2**63
    return np.array([seed, ((lane & 0xFFFF) << 48) | (seq & ((1 << 48) - 1))],
                    np.uint64)


def test_keyed_rng_state_swap_matches_fresh_construction():
    """``KeyedRng.keyed`` re-keys one Philox bit generator in place; the
    resulting state must be indistinguishable from constructing
    ``Generator(Philox(key=...))`` fresh (the claim the sampling-module
    docstring makes).  Draw first so the swapped state starts from a
    dirty buffer — the case the buffer_pos reset must handle."""
    kr = KeyedRng(42)
    kr.keyed(9, 9).random(3)             # dirty the shared buffer
    for lane, seq in ((0, 0), (3, 17), (65535, (1 << 48) - 1)):
        g = kr.keyed(lane, seq)
        fresh = np.random.Generator(
            np.random.Philox(key=_philox_key(42, lane, seq)))
        s, f = g.bit_generator.state, fresh.bit_generator.state
        np.testing.assert_array_equal(s["state"]["key"],
                                      f["state"]["key"])
        np.testing.assert_array_equal(s["state"]["counter"],
                                      f["state"]["counter"])
        assert (s["buffer_pos"], s["has_uint32"], s["uinteger"]) == \
            (f["buffer_pos"], f["has_uint32"], f["uinteger"])
        # stale buffer words are dead with buffer_pos at the refill
        # mark: the drawn streams are identical
        np.testing.assert_array_equal(g.random(8), fresh.random(8))


def test_dispatch_stream_deterministic_and_positioned():
    a, b = KeyedRng(7), KeyedRng(7)
    sa = a.stream(2, 100)
    first = sa.random(4)
    second = sa.random(4)
    assert not np.array_equal(first, second)   # position advances
    sb = b.stream(2, 100)
    np.testing.assert_array_equal(sb.random(4), first)
    np.testing.assert_array_equal(sb.random(4), second)
    # re-keying resets the position; other keys differ
    np.testing.assert_array_equal(a.stream(2, 100).random(4), first)
    assert not np.array_equal(a.stream(2, 101).random(4), first)
    assert not np.array_equal(a.stream(3, 100).random(4), first)
    assert not np.array_equal(KeyedRng(8).stream(2, 100).random(4), first)
    # scalar and vector paths are the same stream
    s1 = a.stream(2, 100)
    s2 = b.stream(2, 100)
    got = np.concatenate([s1.random(1), s1.random(1), s1.random(2)])
    np.testing.assert_array_equal(got, s2.random(4))
    assert ((got >= 0) & (got < 1)).all()


def test_dispatch_stream_multinomial_both_paths():
    p = np.array([0.7, 0.2, 0.1])
    kr = KeyedRng(5)
    small = kr.stream(0, 1).multinomial(_SMALL_DRAW, p)
    assert small.sum() == _SMALL_DRAW
    np.testing.assert_array_equal(
        small, KeyedRng(5).stream(0, 1).multinomial(_SMALL_DRAW, p))
    big = kr.stream(0, 2).multinomial(10_000, p)
    assert big.sum() == 10_000
    # the big draw is the real keyed Philox multinomial
    fresh = np.random.Generator(np.random.Philox(key=_philox_key(5, 0, 2)))
    np.testing.assert_array_equal(big, fresh.multinomial(10_000, p))
    assert abs(big[0] / 10_000 - 0.7) < 0.05


def test_deferred_draw_invariant_under_drain_order():
    """The monitor may drain dispatches in any interleaving; the drawn
    samples for a given (lane, seq) must not change.  Runs the same key
    set through two KeyedRngs in opposite orders, both draw paths."""
    mod_a, mod_b = bound_module(), bound_module()
    kr_a, kr_b = KeyedRng(11), KeyedRng(11)
    keys = [(0, 3), (1, 0), (0, 4), (2, 9), (1, 1)]
    budgets = [1, 7, _SMALL_DRAW + 20, 2, 5]
    got_a = {k: sampling.draw_samples(mod_a, n, kr_a.stream(*k))
             for k, n in zip(keys, budgets)}
    got_b = {k: sampling.draw_samples(mod_b, n, kr_b.stream(*k))
             for k, n in zip(reversed(keys), reversed(budgets))}
    assert got_a == got_b
    for k, n in zip(keys, budgets):
        assert sum(s.count for s in got_a[k]) == n   # budget exact


def test_draw_samples_small_path_matches_distribution():
    """The small-budget inverse-CDF draw must produce the same marginal
    distribution as the multinomial it replaces: over many keyed draws
    the empirical op frequencies converge to the modeled weights."""
    mod = bound_module()
    w, _stall = sampling.op_weights(mod)
    p = w / w.sum()
    kr = KeyedRng(123)
    counts = np.zeros(len(p))
    n_draws, budget = 2000, 4
    for seq in range(n_draws):
        for s in sampling.draw_samples(mod, budget, kr.stream(0, seq)):
            # interior leaves fold back onto their op for the marginal
            counts[s.op_index] += s.count
    freq = counts / counts.sum()
    np.testing.assert_allclose(freq, p, atol=0.02)
