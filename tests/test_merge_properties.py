"""Merge-algebra property tests (ISSUE 4 satellite).

The merge contract, stated as algebra over randomized profile sets:

- **completeness**: for ANY partition of the profiles into shards, in ANY
  shard order, shard-then-merge is byte-identical to one-shot
  ``aggregate()`` over the union;
- **associativity**: any merge tree over the shards lands on the same
  bytes as the flat merge;
- **incrementality**: ``aggregate(new, base_db=...)`` at any split point
  equals the one-shot;
- **driver invariance** (ISSUE 5): the serial / thread / process shard
  drivers, at any worker count, land on the same bytes — databases AND
  converted traces (the pipeline driver is the sharding above run by an
  executor and folded through ``merge_databases``).

Hypothesis draws the profile set (seed), the shard assignment, and the
shard permutation; the pinned ``test_properties_hold_on_fixed_example``
exercises the same bodies without hypothesis so the logic runs in
minimal environments too (the ``@given`` tests skip there, see
tests/hypothesis_compat.py).
"""
import os

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.aggregate import aggregate
from repro.core.merge import merge_databases
from test_aggregate_equiv import synth_inputs
from test_merge import db_bytes, meta_of, traces_of


def _build(tmp, seed, n_profiles):
    os.makedirs(tmp, exist_ok=True)
    paths, traces = synth_inputs(tmp, seed=seed, n_profiles=n_profiles)
    one = str(tmp / "one")
    aggregate(paths, one, trace_paths=traces)
    return paths, one


def _aggregate_shards(tmp, paths, shard_of):
    """Aggregate each shard (profile i -> shard shard_of[i]) with a
    shard-dependent n_ranks, so canonicalization is doing real work."""
    shards = {}
    for i, s in enumerate(shard_of):
        shards.setdefault(s, []).append(paths[i])
    dirs = []
    for s, sp in sorted(shards.items()):
        d = str(tmp / f"shard{s}")
        aggregate(sp, d, n_ranks=1 + s % 3, n_threads=1 + s % 2,
                  trace_paths=traces_of(sp))
        dirs.append(d)
    return dirs


def check_sharding_invariance(tmp, seed, shard_of, reverse):
    paths, one = _build(tmp, seed, n_profiles=len(shard_of))
    dirs = _aggregate_shards(tmp, paths, shard_of)
    if reverse:
        dirs = list(reversed(dirs))
    merged = str(tmp / "merged")
    merge_databases(dirs, merged)
    assert db_bytes(merged) == db_bytes(one)
    assert meta_of(merged) == meta_of(one)


def check_associativity(tmp, seed, shard_of):
    paths, one = _build(tmp, seed, n_profiles=len(shard_of))
    dirs = _aggregate_shards(tmp, paths, shard_of)
    # left fold two at a time vs flat N-way merge
    acc = dirs[0]
    for i, d in enumerate(dirs[1:]):
        nxt = str(tmp / f"fold{i}")
        merge_databases([acc, d], nxt)
        acc = nxt
    flat = str(tmp / "flat")
    merge_databases(dirs, flat)
    assert db_bytes(acc) == db_bytes(flat)
    assert db_bytes(flat) == db_bytes(one)


def check_incremental(tmp, seed, n_profiles, split):
    split = max(1, min(n_profiles - 1, split))
    paths, one = _build(tmp, seed, n_profiles=n_profiles)
    inc = str(tmp / "inc")
    aggregate(paths[:split], inc, trace_paths=traces_of(paths[:split]))
    aggregate(paths[split:], inc, base_db=inc,
              trace_paths=traces_of(paths[split:]))
    assert db_bytes(inc) == db_bytes(one)


def check_driver_invariance(tmp, seed, n_profiles, driver, workers):
    """ISSUE 5: every shard driver at any worker count lands on the
    serial one-shot bytes — database files, meta, and the converted
    per-trace outputs."""
    import os
    paths, one = _build(tmp, seed, n_profiles=n_profiles)
    out = str(tmp / f"drv_{driver}_{workers}")
    aggregate(paths, out, trace_paths=traces_of(paths),
              driver=driver, workers=workers)
    assert db_bytes(out) == db_bytes(one)
    assert meta_of(out) == meta_of(one)
    for t in traces_of(paths):
        b = os.path.basename(t)
        assert open(os.path.join(out, b), "rb").read() == \
            open(os.path.join(one, b), "rb").read()


@given(st.integers(0, 10_000),
       st.lists(st.integers(0, 3), min_size=2, max_size=6),
       st.booleans())
@settings(max_examples=8, deadline=None)
def test_any_sharding_merges_to_one_shot_bytes(tmp_path_factory, seed,
                                               shard_of, reverse):
    check_sharding_invariance(tmp_path_factory.mktemp("shard"), seed,
                              shard_of, reverse)


@given(st.integers(0, 10_000),
       st.lists(st.integers(0, 2), min_size=3, max_size=6))
@settings(max_examples=6, deadline=None)
def test_merge_is_associative_property(tmp_path_factory, seed, shard_of):
    check_associativity(tmp_path_factory.mktemp("assoc"), seed, shard_of)


@given(st.integers(0, 10_000), st.integers(2, 6), st.integers(1, 5))
@settings(max_examples=6, deadline=None)
def test_incremental_equals_one_shot_property(tmp_path_factory, seed,
                                              n_profiles, split):
    check_incremental(tmp_path_factory.mktemp("inc"), seed, n_profiles,
                      split)


@given(st.integers(0, 10_000), st.integers(2, 7),
       st.sampled_from(["serial", "thread", "process"]),
       st.integers(1, 5))
@settings(max_examples=6, deadline=None)
def test_any_driver_any_worker_count_is_byte_identical(tmp_path_factory,
                                                       seed, n_profiles,
                                                       driver, workers):
    check_driver_invariance(tmp_path_factory.mktemp("drv"), seed,
                            n_profiles, driver, workers)


def test_properties_hold_on_fixed_example(tmp_path):
    """The property bodies on one pinned draw — runs with or without
    hypothesis installed."""
    check_sharding_invariance(tmp_path / "a", seed=7,
                              shard_of=[0, 2, 1, 0, 2], reverse=True)
    check_associativity(tmp_path / "b", seed=8, shard_of=[1, 0, 2, 1])
    check_incremental(tmp_path / "c", seed=9, n_profiles=4, split=2)
    check_driver_invariance(tmp_path / "d", seed=10, n_profiles=5,
                            driver="process", workers=3)


def test_property_suite_active_when_hypothesis_present():
    import importlib
    assert HAVE_HYPOTHESIS == (
        importlib.util.find_spec("hypothesis") is not None)
