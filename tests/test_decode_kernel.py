"""Flash-decode Pallas kernel vs the validated jnp decode attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models.attention import decode_attention

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,D,Smax,bk", [
    (1, 4, 4, 64, 512, 256),    # MHA
    (2, 8, 2, 64, 1024, 512),   # GQA 4:1
    (1, 8, 1, 32, 512, 128),    # MQA
])
def test_flash_decode_vs_ref(B, H, Hkv, D, Smax, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = (jax.random.normal(ks[0], (B, H, D)) * 0.5).astype(dtype)
    kc = (jax.random.normal(ks[1], (B, Smax, Hkv, D)) * 0.5).astype(dtype)
    vc = (jax.random.normal(ks[2], (B, Smax, Hkv, D)) * 0.5).astype(dtype)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)
    for length in (1, Smax // 3, Smax):
        out = ops.flash_decode(q, kc, vc, jnp.int32(length), block_kv=bk)
        want = decode_attention(q, kc, vc, jnp.int32(length))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   err_msg=f"len={length}", **tol)


def test_flash_decode_blocks_beyond_length_are_skipped():
    """Stale data beyond `length` (reused cache buffers hold the previous
    request's KV) must not leak into the output."""
    ks = jax.random.split(KEY, 3)
    B, H, D, Smax = 1, 2, 16, 256
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, Smax, H, D))
    vc = jax.random.normal(ks[2], (B, Smax, H, D))
    kc_poison = kc.at[:, 100:].set(1e9)
    vc_poison = vc.at[:, 100:].set(-1e9)
    out = ops.flash_decode(q, kc_poison, vc_poison, jnp.int32(100),
                           block_kv=64)
    want = decode_attention(q, kc, vc, jnp.int32(100))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
