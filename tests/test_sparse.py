"""PMS / CMS sparse-cube formats (paper §6.2)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.sparse import (CMSReader, PMSReader, ProfileValues,
                               dense_cube_nbytes, write_cms, write_pms)


def make_profiles(rng, n_profiles, n_ctx, n_metrics, density=0.1):
    profs = []
    dense = np.zeros((n_profiles, n_ctx, n_metrics))
    for p in range(n_profiles):
        mask = rng.random((n_ctx, n_metrics)) < density
        ctx, met = np.nonzero(mask)
        vals = rng.random(len(ctx)) + 0.5
        dense[p, ctx, met] = vals
        profs.append(ProfileValues(p, ctx.astype(np.uint32),
                                   met.astype(np.uint32), vals))
    return profs, dense


def test_cms_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    profs, dense = make_profiles(rng, 5, 40, 12)
    path = str(tmp_path / "m.cms")
    info = write_cms(path, profs, n_workers=3)
    r = CMSReader(path)
    assert r.header["n_profiles"] == 5
    for ctx in range(40):
        for met in range(12):
            for p in range(5):
                assert r.lookup(ctx, met, p) == pytest.approx(
                    dense[p, ctx, met]), (ctx, met, p)


def test_cms_metric_values_vector(tmp_path):
    rng = np.random.default_rng(1)
    profs, dense = make_profiles(rng, 8, 20, 6, density=0.3)
    path = str(tmp_path / "m.cms")
    write_cms(path, profs)
    r = CMSReader(path)
    pids, vals = r.metric_values(3, 2)
    want = {p: dense[p, 3, 2] for p in range(8) if dense[p, 3, 2] != 0}
    assert {int(p): float(v) for p, v in zip(pids, vals)} == pytest.approx(
        want)


def test_pms_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    profs, dense = make_profiles(rng, 4, 30, 10)
    path = str(tmp_path / "m.pms")
    write_pms(path, profs, n_workers=2)
    r = PMSReader(path)
    for p in range(4):
        for ctx in range(30):
            got = r.context_values(p, ctx)
            want = {m: dense[p, ctx, m] for m in range(10)
                    if dense[p, ctx, m] != 0}
            assert got == pytest.approx(want)


def test_sparse_much_smaller_than_dense(tmp_path):
    """The §8.2 claim at similar sparsity: sparse << dense."""
    rng = np.random.default_rng(3)
    n_p, n_c, n_m = 64, 500, 120
    profs, _ = make_profiles(rng, n_p, n_c, n_m, density=0.01)
    info = write_cms(str(tmp_path / "m.cms"), profs)
    dense_bytes = dense_cube_nbytes(n_p, n_c, n_m)
    assert info["bytes"] * 10 < dense_bytes, (
        f"sparse {info['bytes']} vs dense {dense_bytes}")


def test_missing_context_and_metric(tmp_path):
    rng = np.random.default_rng(4)
    profs, _ = make_profiles(rng, 2, 10, 4, density=0.5)
    path = str(tmp_path / "m.cms")
    write_cms(path, profs)
    r = CMSReader(path)
    assert r.lookup(999, 0, 0) == 0.0
    assert r.lookup(0, 999, 0) == 0.0
    assert r.lookup(0, 0, 999) == 0.0


@given(st.integers(1, 6), st.integers(1, 25), st.integers(1, 8),
       st.floats(0.05, 0.9), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_cms_pms_agree_property(tmp_path_factory, n_p, n_c, n_m, density,
                                seed):
    """Property: both cubes return identical values for every coordinate."""
    tmp = tmp_path_factory.mktemp("cube")
    rng = np.random.default_rng(seed)
    profs, dense = make_profiles(rng, n_p, n_c, n_m, density)
    write_cms(str(tmp / "m.cms"), profs, n_workers=2)
    write_pms(str(tmp / "m.pms"), profs, n_workers=2)
    cms = CMSReader(str(tmp / "m.cms"))
    pms = PMSReader(str(tmp / "m.pms"))
    for p in range(n_p):
        for c in range(n_c):
            row = pms.context_values(p, c)
            for m in range(n_m):
                assert cms.lookup(c, m, p) == pytest.approx(
                    row.get(m, 0.0)), (p, c, m)
