"""PMS / CMS sparse-cube formats (paper §6.2)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.sparse import (CMSReader, PMSReader, ProfileValues,
                               dense_cube_nbytes, read_cms, read_pms,
                               write_cms, write_pms)


def reconstruct_dense(pvals, n_profiles, n_ctx, n_metrics):
    out = np.zeros((n_profiles, n_ctx, n_metrics))
    for pv in pvals:
        out[pv.profile_id, pv.ctx, pv.metric] = pv.values
    return out


def make_profiles(rng, n_profiles, n_ctx, n_metrics, density=0.1):
    profs = []
    dense = np.zeros((n_profiles, n_ctx, n_metrics))
    for p in range(n_profiles):
        mask = rng.random((n_ctx, n_metrics)) < density
        ctx, met = np.nonzero(mask)
        vals = rng.random(len(ctx)) + 0.5
        dense[p, ctx, met] = vals
        profs.append(ProfileValues(p, ctx.astype(np.uint32),
                                   met.astype(np.uint32), vals))
    return profs, dense


def test_cms_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    profs, dense = make_profiles(rng, 5, 40, 12)
    path = str(tmp_path / "m.cms")
    info = write_cms(path, profs, n_workers=3)
    r = CMSReader(path)
    assert r.header["n_profiles"] == 5
    for ctx in range(40):
        for met in range(12):
            for p in range(5):
                assert r.lookup(ctx, met, p) == pytest.approx(
                    dense[p, ctx, met]), (ctx, met, p)


def test_cms_metric_values_vector(tmp_path):
    rng = np.random.default_rng(1)
    profs, dense = make_profiles(rng, 8, 20, 6, density=0.3)
    path = str(tmp_path / "m.cms")
    write_cms(path, profs)
    r = CMSReader(path)
    pids, vals = r.metric_values(3, 2)
    want = {p: dense[p, 3, 2] for p in range(8) if dense[p, 3, 2] != 0}
    assert {int(p): float(v) for p, v in zip(pids, vals)} == pytest.approx(
        want)


def test_pms_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    profs, dense = make_profiles(rng, 4, 30, 10)
    path = str(tmp_path / "m.pms")
    write_pms(path, profs, n_workers=2)
    r = PMSReader(path)
    for p in range(4):
        for ctx in range(30):
            got = r.context_values(p, ctx)
            want = {m: dense[p, ctx, m] for m in range(10)
                    if dense[p, ctx, m] != 0}
            assert got == pytest.approx(want)


def test_sparse_much_smaller_than_dense(tmp_path):
    """The §8.2 claim at similar sparsity: sparse << dense."""
    rng = np.random.default_rng(3)
    n_p, n_c, n_m = 64, 500, 120
    profs, _ = make_profiles(rng, n_p, n_c, n_m, density=0.01)
    info = write_cms(str(tmp_path / "m.cms"), profs)
    dense_bytes = dense_cube_nbytes(n_p, n_c, n_m)
    assert info["bytes"] * 10 < dense_bytes, (
        f"sparse {info['bytes']} vs dense {dense_bytes}")


def test_missing_context_and_metric(tmp_path):
    rng = np.random.default_rng(4)
    profs, _ = make_profiles(rng, 2, 10, 4, density=0.5)
    path = str(tmp_path / "m.cms")
    write_cms(path, profs)
    r = CMSReader(path)
    assert r.lookup(999, 0, 0) == 0.0
    assert r.lookup(0, 999, 0) == 0.0
    assert r.lookup(0, 0, 999) == 0.0


# --------------------------------------------------------------------------
# Full-cube readers (ISSUE 4: the merge subsystem re-reads shard cubes)
# --------------------------------------------------------------------------
def test_read_pms_dense_reconstruction(tmp_path):
    rng = np.random.default_rng(5)
    profs, dense = make_profiles(rng, 4, 25, 9, density=0.2)
    path = str(tmp_path / "m.pms")
    write_pms(path, profs, n_workers=2)
    got = read_pms(path)
    assert [pv.profile_id for pv in got] == [0, 1, 2, 3]
    assert np.array_equal(reconstruct_dense(got, 4, 25, 9), dense)


def test_read_cms_dense_reconstruction(tmp_path):
    rng = np.random.default_rng(6)
    profs, dense = make_profiles(rng, 4, 25, 9, density=0.2)
    path = str(tmp_path / "m.cms")
    write_cms(path, profs, n_workers=2)
    got = read_cms(path)
    assert np.array_equal(reconstruct_dense(got, 4, 25, 9), dense)


def test_pms_write_read_write_is_byte_identical(tmp_path):
    """read_pms returns planes bitwise as written (row-major order), so a
    write-back round-trips to identical bytes — what the database merge
    relies on for the one-shot byte-identity contract."""
    rng = np.random.default_rng(7)
    profs, _ = make_profiles(rng, 3, 30, 8, density=0.15)
    a = str(tmp_path / "a.pms")
    write_pms(a, profs, n_workers=1)
    b = str(tmp_path / "b.pms")
    write_pms(b, read_pms(a), n_workers=1)
    assert open(a, "rb").read() == open(b, "rb").read()


def test_readers_roundtrip_profile_data_dense_matrix(tmp_path):
    """End-to-end with the profile format: a measured profile's exclusive
    dense matrix survives write_pms/write_cms -> reader -> dense."""
    from repro.core.cct import CCT, Frame, HOST
    from repro.core.metrics import default_registry
    from repro.core.profmt import read_profile, write_profile
    reg = default_registry()
    cct = CCT()
    rng = np.random.default_rng(8)
    for i in range(12):
        n = cct.insert_path([Frame(HOST, f"f{i % 5}", "a.py", i % 3)])
        n.metrics.add(reg.kind("cpu"), "time_ns", float(rng.integers(1, 99)))
    p = str(tmp_path / "p.rpro")
    write_profile(p, cct, reg, {"rank": 0}, [])
    prof = read_profile(p)
    n_metrics = len(prof.metrics)
    dense = prof.dense_matrix(n_metrics)
    # node_ids index rows of dense_matrix; use them as ctx ids directly
    rows = {int(n): i for i, n in enumerate(prof.node_ids)}
    ctx, met = np.nonzero(dense)
    pv = ProfileValues(0, np.array([int(prof.node_ids[c]) for c in ctx],
                                   np.uint32).astype(np.uint32),
                       met.astype(np.uint32), dense[ctx, met])
    write_pms(str(tmp_path / "m.pms"), [pv], n_workers=1)
    write_cms(str(tmp_path / "m.cms"), [pv], n_workers=1)
    for got in (read_pms(str(tmp_path / "m.pms"))[0],
                read_cms(str(tmp_path / "m.cms"))[0]):
        back = np.zeros_like(dense)
        back[[rows[int(c)] for c in got.ctx], got.metric] = got.values
        assert np.array_equal(back, dense)


def test_read_empty_cubes(tmp_path):
    write_pms(str(tmp_path / "e.pms"), [], n_workers=1)
    write_cms(str(tmp_path / "e.cms"), [], n_workers=1)
    assert read_pms(str(tmp_path / "e.pms")) == []
    assert read_cms(str(tmp_path / "e.cms")) == []


def test_read_pms_keeps_empty_profile_plane(tmp_path):
    """A profile with no nonzero values still owns a (empty) plane — it
    must survive the merge round trip to keep profile ids canonical."""
    pv0 = ProfileValues(0, np.zeros(0, np.uint32), np.zeros(0, np.uint32),
                        np.zeros(0))
    pv1 = ProfileValues(1, np.array([2], np.uint32),
                        np.array([1], np.uint32), np.array([3.5]))
    path = str(tmp_path / "m.pms")
    write_pms(path, [pv0, pv1], n_workers=1)
    got = read_pms(path)
    assert [pv.profile_id for pv in got] == [0, 1]
    assert len(got[0].values) == 0
    assert got[1].values.tolist() == [3.5]


@given(st.integers(1, 6), st.integers(1, 25), st.integers(1, 8),
       st.floats(0.05, 0.9), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_cms_pms_agree_property(tmp_path_factory, n_p, n_c, n_m, density,
                                seed):
    """Property: both cubes return identical values for every coordinate."""
    tmp = tmp_path_factory.mktemp("cube")
    rng = np.random.default_rng(seed)
    profs, dense = make_profiles(rng, n_p, n_c, n_m, density)
    write_cms(str(tmp / "m.cms"), profs, n_workers=2)
    write_pms(str(tmp / "m.pms"), profs, n_workers=2)
    cms = CMSReader(str(tmp / "m.cms"))
    pms = PMSReader(str(tmp / "m.pms"))
    for p in range(n_p):
        for c in range(n_c):
            row = pms.context_values(p, c)
            for m in range(n_m):
                assert cms.lookup(c, m, p) == pytest.approx(
                    row.get(m, 0.0)), (p, c, m)
