"""Equivalence of the vectorized aggregation pipeline with a retained
reference implementation (ISSUE 1 tentpole contract).

The reference below is the *pre-vectorization* algorithm, kept small and
readable: per-profile dense scatter in file order, dense reverse-id sweep
for inclusive propagation, accumulators folded in profile order.  The
production pipeline (sparse COO + level-order sweep + communication-free
workers) must reproduce it **bit for bit** — stats arrays via
``np.array_equal``, CMS/PMS cubes and converted traces via file-byte
comparison — on randomized synthetic CCTs and under parallel execution.

Since ISSUE 4 the database is *canonical* (ids independent of
n_ranks/path order, see docs/aggregation.md): the reference applies the
same shared ``canonical_order`` / ``profile_sort_key`` renumbering, so
what this file pins is everything else — the sparse level-order sweep,
the lock-free parallel fold, and the cube/trace writers — against the
dense serial algorithms.
"""
import json
import os

import numpy as np
import pytest

from repro.core.aggregate import (Database, GlobalTree, aggregate,
                                  apply_order, canonical_order,
                                  profile_sort_key)
from repro.core.cct import CCT, Frame, GPU_OP, HOST, PLACEHOLDER
from repro.core.metrics import default_registry
from repro.core.profmt import read_profile, write_profile
from repro.core.sparse import ProfileValues, write_cms, write_pms
from repro.core.trace import TraceWriter, read_trace


# --------------------------------------------------------------------------
# Synthetic inputs: randomized CCTs with overlapping call paths
# --------------------------------------------------------------------------
def synth_inputs(tmp_path, seed, n_profiles=7, with_traces=True):
    rng = np.random.default_rng(seed)
    reg = default_registry()
    gk, cpu, gi = reg.kind("gpu_kernel"), reg.kind("cpu"), reg.kind("gpu_inst")
    paths, traces = [], []
    for p in range(n_profiles):
        cct = CCT()
        nodes = []
        for _ in range(int(rng.integers(20, 60))):
            depth = 1 + int(rng.integers(5))
            # a small shared frame pool forces cross-profile unification
            frames = [Frame(HOST, f"fn{rng.integers(12)}",
                            f"file{rng.integers(3)}.py",
                            int(rng.integers(40)))
                      for _ in range(depth)]
            node = cct.insert_path(frames)
            node.metrics.add(cpu, "time_ns", float(rng.integers(1, 10_000)))
            nodes.append(node)
        for k in range(int(rng.integers(2, 6))):
            host = nodes[int(rng.integers(len(nodes)))]
            ph = cct.get_or_insert(host, Frame(PLACEHOLDER, f"kernel:k{k}",
                                               "0", 0))
            ph.metrics.add(gk, "invocations", float(rng.integers(1, 9)))
            ph.metrics.add(gk, "time_ns", float(rng.integers(1, 50_000)))
            op = cct.insert_path([Frame(GPU_OP, f"op{k}", f"mod{k}", k)],
                                 parent=ph)
            op.metrics.add(gi, "samples", float(rng.integers(1, 300)))
        path = str(tmp_path / f"p{p}.rpro")
        write_profile(path, cct, reg, {"rank": p, "type": "cpu"}, [])
        paths.append(path)
        if with_traces:
            tw = TraceWriter(path.replace(".rpro", ".rtrc"), {"rank": p})
            t = 0
            for node in nodes[:10]:
                tw.append(t, t + 10, node.node_id)
                t += 10
            tw.close()
            traces.append(tw.path)
    return paths, traces


# --------------------------------------------------------------------------
# Reference implementation (retained pre-vectorization algorithm)
# --------------------------------------------------------------------------
class RefTree:
    """Per-node dict tree keyed by (parent, Frame) — the original
    unification data structure."""

    def __init__(self):
        self.frames = [Frame("root", "<program root>")]
        self.parents = [-1]
        self._index = {}

    def child(self, parent, frame):
        key = (parent, frame)
        gid = self._index.get(key)
        if gid is None:
            gid = len(self.frames)
            self.frames.append(frame)
            self.parents.append(parent)
            self._index[key] = gid
        return gid

    def merge_paths(self, prof):
        n = len(prof.node_ids)
        l2g = np.zeros(int(prof.node_ids.max()) + 1 if n else 1, np.int64)
        for i in range(n):
            nid, par = int(prof.node_ids[i]), int(prof.parents[i])
            if par < 0:
                l2g[nid] = 0
                continue
            l2g[nid] = self.child(int(l2g[par]), prof.frames[i])
        return l2g

    def merge_tree(self, other):
        mapping = np.zeros(len(other.frames), np.int64)
        for gid in range(1, len(other.frames)):
            mapping[gid] = self.child(int(mapping[other.parents[gid]]),
                                      other.frames[gid])
        return mapping


def ref_aggregate(profile_paths, n_ranks):
    """Reference pipeline: same phase structure, scalar algorithms."""
    ranks = [[] for _ in range(n_ranks)]
    for i, p in enumerate(profile_paths):
        ranks[i % n_ranks].append(p)
    rank_results = []
    for paths in ranks:
        tree = RefTree()
        profs = []
        for path in paths:
            prof = read_profile(path)
            profs.append((path, prof, tree.merge_paths(prof)))
        rank_results.append((tree, profs))
    root = rank_results[0][0]
    mappings = [None] + [root.merge_tree(t)
                         for t, _ in rank_results[1:]]
    # canonical renumbering: the shared pure functions, applied to the
    # reference tree too — both sides must land on the same canonical ids
    # (the ids themselves are exercised by the merge/property suites)
    new_id = canonical_order(root.frames, root.parents)
    frames_c, parents_c = apply_order(root.frames, root.parents, new_id)
    all_profiles = []
    for (tree, profs), conv in zip(rank_results, mappings):
        for path, prof, mapping in profs:
            gmap = mapping if conv is None else conv[mapping]
            all_profiles.append((path, prof, new_id[gmap]))

    metrics = all_profiles[0][1].metrics if all_profiles else []
    n_metrics = len(metrics)
    n_ctx = len(frames_c)
    parents = parents_c

    items = []
    for path, prof, gmap in all_profiles:
        dense = np.zeros((n_ctx, n_metrics))
        node_of_value = np.zeros(len(prof.values), np.int64)
        for nid, start, count in prof.ranges:
            node_of_value[start:start + count] = gmap[int(nid)]
        np.add.at(dense, (node_of_value, prof.value_mids.astype(np.int64)),
                  prof.values)
        # dense reverse-id sweep: canonical ids stay topological, so each
        # row folds into its parent exactly once, children in decreasing id
        for gid in range(n_ctx - 1, 0, -1):
            p = parents[gid]
            if p >= 0:
                dense[p] += dense[gid]
        nz_ctx, nz_met = np.nonzero(dense)
        vals = dense[nz_ctx, nz_met]
        items.append((prof.identity, nz_ctx, nz_met, vals))

    # canonical profile order (shared key), then the serial fold
    items.sort(key=lambda it: profile_sort_key(*it))
    acc = {"sum": np.zeros((n_ctx, n_metrics)),
           "min": np.full((n_ctx, n_metrics), np.inf),
           "max": np.full((n_ctx, n_metrics), -np.inf),
           "sumsq": np.zeros((n_ctx, n_metrics)),
           "count": np.zeros((n_ctx, n_metrics))}
    pvals, identities = [], {}
    for pidx, (ident, nz_ctx, nz_met, vals) in enumerate(items):
        acc["sum"][nz_ctx, nz_met] += vals
        np.minimum.at(acc["min"], (nz_ctx, nz_met), vals)
        np.maximum.at(acc["max"], (nz_ctx, nz_met), vals)
        acc["sumsq"][nz_ctx, nz_met] += vals ** 2
        acc["count"][nz_ctx, nz_met] += 1
        pvals.append(ProfileValues(pidx, nz_ctx.astype(np.uint32),
                                   nz_met.astype(np.uint32), vals))
        identities[pidx] = ident

    count = np.maximum(acc["count"], 1)
    mean = acc["sum"] / count
    var = np.maximum(acc["sumsq"] / count - mean ** 2, 0.0)
    std = np.sqrt(var)
    stats = {"sum": acc["sum"],
             "min": np.where(np.isfinite(acc["min"]), acc["min"], 0.0),
             "mean": mean,
             "max": np.where(np.isfinite(acc["max"]), acc["max"], 0.0),
             "std": std,
             "cov": np.where(mean != 0,
                             std / np.maximum(np.abs(mean), 1e-30), 0.0),
             "count": acc["count"]}
    return (frames_c, parents_c), stats, pvals, all_profiles


# --------------------------------------------------------------------------
# Equivalence tests
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed,n_ranks,n_threads",
                         [(0, 1, 1), (1, 3, 2), (2, 4, 4)])
def test_bitwise_equivalence(tmp_path, seed, n_ranks, n_threads):
    paths, traces = synth_inputs(tmp_path, seed)
    out = str(tmp_path / "db")
    db = aggregate(paths, out, n_ranks=n_ranks, n_threads=n_threads,
                   trace_paths=traces)
    (frames_c, parents_c), stats, pvals, all_profiles = \
        ref_aggregate(paths, n_ranks)

    # tree identity: same frames in the same canonical order
    assert db.frames == frames_c
    assert list(db.parents) == list(parents_c)

    # stats arrays: bitwise equal
    for k, ref in stats.items():
        assert np.array_equal(db.stats[k], ref), f"stat {k} diverged"

    # sparse cubes: file bytes equal to cubes built from reference pvals
    ref_cms = str(tmp_path / "ref.cms")
    ref_pms = str(tmp_path / "ref.pms")
    write_cms(ref_cms, pvals, n_workers=1)
    write_pms(ref_pms, pvals, n_workers=1)
    assert open(db.cms_path(), "rb").read() == open(ref_cms, "rb").read()
    assert open(db.pms_path(), "rb").read() == open(ref_pms, "rb").read()

    # trace conversion: byte-identical to the reference gmap rewrite
    gmap_of = {path: gmap for path, _, gmap in all_profiles}
    for tpath in traces:
        td = read_trace(tpath)
        gmap = gmap_of[tpath.replace(".rtrc", ".rpro")]
        ref_t = str(tmp_path / ("ref_" + os.path.basename(tpath)))
        tw = TraceWriter(ref_t, td.identity)
        for s, e, c in zip(td.starts, td.ends, td.ctx):
            tw.append(int(s), int(e), int(gmap[int(c)]))
        tw.close()
        got = os.path.join(out, os.path.basename(tpath))
        assert open(got, "rb").read() == open(ref_t, "rb").read()


def test_parallel_is_deterministic(tmp_path):
    """Lock-free accumulation must not depend on thread scheduling."""
    paths, _ = synth_inputs(tmp_path, 3, with_traces=False)
    blobs = []
    for rep in range(2):
        out = str(tmp_path / f"db{rep}")
        aggregate(paths, out, n_ranks=4, n_threads=4)
        blobs.append((open(os.path.join(out, "stats.npz"), "rb").read(),
                      open(os.path.join(out, "metrics.cms"), "rb").read()))
    assert blobs[0] == blobs[1]


def test_empty_profile_paths(tmp_path):
    """No profiles: a root-only database, not an IndexError."""
    out = str(tmp_path / "db")
    db = aggregate([], out, n_ranks=4, n_threads=4)
    assert len(db.frames) == 1
    assert db.metrics == []
    assert db.stats["sum"].shape == (1, 0)
    db2 = Database.load(out)
    assert len(db2.frames) == 1


def test_out_of_range_trace_ctx_warns_and_maps_to_root(tmp_path):
    paths, traces = synth_inputs(tmp_path, 4, n_profiles=2)
    # corrupt one trace with a ctx id far outside the profile's id map
    td = read_trace(traces[0])
    tw = TraceWriter(traces[0], td.identity)
    tw.append(0, 5, int(td.ctx[0]))
    tw.append(5, 9, 10_000_000)
    tw.close()
    out = str(tmp_path / "db")
    with pytest.warns(RuntimeWarning, match="outside the profile's id map"):
        aggregate(paths, out, n_ranks=1, n_threads=1, trace_paths=traces)
    conv = read_trace(os.path.join(out, os.path.basename(traces[0])))
    assert conv.ctx[1] == 0, "out-of-range event must attribute to root"


def test_children_index_matches_scan(tmp_path):
    paths, _ = synth_inputs(tmp_path, 5, n_profiles=3, with_traces=False)
    db = aggregate(paths, str(tmp_path / "db"), n_ranks=2, n_threads=2)
    parents = np.asarray(db.parents)
    for gid in range(len(db.frames)):
        assert db.children_of(gid) == \
            [i for i, p in enumerate(parents) if p == gid]


def test_merge_paths_matches_reference_tree(tmp_path):
    paths, _ = synth_inputs(tmp_path, 6, n_profiles=4, with_traces=False)
    gt, rt = GlobalTree(), RefTree()
    for p in paths:
        prof = read_profile(p)
        gmap_v = gt.merge_paths(prof)
        gmap_r = rt.merge_paths(prof)
        assert np.array_equal(gmap_v, gmap_r)
    assert gt.frames == rt.frames
    assert list(gt.parents) == rt.parents


def test_trace_append_many_equivalence(tmp_path):
    rng = np.random.default_rng(0)
    starts = np.sort(rng.integers(0, 1000, 50)).astype(np.int64)
    starts[20] = 0   # force an out-of-order event
    ends = starts + 5
    ctx = rng.integers(0, 99, 50).astype(np.int64)
    a, b = str(tmp_path / "a.rtrc"), str(tmp_path / "b.rtrc")
    wa = TraceWriter(a, {"rank": 0})
    for s, e, c in zip(starts, ends, ctx):
        wa.append(int(s), int(e), int(c))
    wa.close()
    wb = TraceWriter(b, {"rank": 0})
    wb.append_many(starts[:7], ends[:7], ctx[:7])     # mixed bulk/scalar
    for s, e, c in zip(starts[7:11], ends[7:11], ctx[7:11]):
        wb.append(int(s), int(e), int(c))
    wb.append_many(starts[11:], ends[11:], ctx[11:])
    wb.close()
    assert wa.out_of_order and wb.out_of_order
    assert open(a, "rb").read() == open(b, "rb").read()
