"""Golden-file regression tests for the user-facing text reports
(core/viewer.py and traceview/render.py).

The views are the product surface of this tool — the paper's hpcviewer /
hpctraceviewer screens rendered as text — so formatting refactors must
not silently change them.  Each test renders a fully deterministic
fixture database and compares byte-for-byte against a checked-in golden
under ``tests/goldens/``.

To intentionally change the output format::

    PYTHONPATH=src python -m pytest tests/test_goldens.py --update-goldens

then review the golden diff like any other code change.
"""
import os

import numpy as np
import pytest

from repro.core.aggregate import aggregate
from repro.core.cct import CCT, Frame, HOST, PLACEHOLDER
from repro.core.metrics import GPU_COUNTER_METRICS, default_registry
from repro.core.profmt import write_profile
from repro.core.trace import TraceWriter
from repro.counters import COUNTER_INDEX

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


def check_golden(name: str, text: str, update: bool) -> None:
    path = os.path.join(GOLDEN_DIR, name)
    if update:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            f.write(text + "\n")
        pytest.skip(f"golden {name} updated")
    assert os.path.exists(path), \
        f"missing golden {name}; run pytest --update-goldens to create it"
    with open(path) as f:
        expect = f.read()
    assert text + "\n" == expect, (
        f"{name} drifted from its golden.  If the change is intentional, "
        "re-run with --update-goldens and review the diff.")


@pytest.fixture(scope="module")
def fixture_db(tmp_path_factory):
    """Deterministic 4-rank measurement: two host frames, two kernels
    (one with counter data), a copy, and aligned traces."""
    tmp = tmp_path_factory.mktemp("goldens_db")
    reg = default_registry()
    kkind = reg.kind("gpu_kernel")
    ckind = reg.kind("gpu_counter")
    pkind = reg.kind("gpu_copy")
    cpu = reg.kind("cpu")
    cvec = np.zeros(len(GPU_COUNTER_METRICS))
    cvec[COUNTER_INDEX["elapsed_ns"]] = 1_000.0
    cvec[COUNTER_INDEX["active_ns"]] = 250.0
    cvec[COUNTER_INDEX["flops"]] = 98_500_000.0
    cvec[COUNTER_INDEX["hbm_bytes"]] = 197_000_000.0
    cvec[COUNTER_INDEX["replay_passes"]] = 2.0
    paths, traces = [], []
    for r in range(4):
        cct = CCT()
        main = cct.insert_path([Frame(HOST, "main", "app.py", 1)])
        step = cct.insert_path([Frame(HOST, "step", "app.py", 10)],
                               parent=main)
        ph = cct.get_or_insert(step,
                               Frame(PLACEHOLDER, "kernel:train", "0", 0))
        ph.metrics.add(kkind, "invocations", 2 + r)
        ph.metrics.add(kkind, "time_ns", 400.0 * (r + 1))
        ph.metrics.add_vec(ckind, cvec * (r + 1))
        ph2 = cct.get_or_insert(step,
                                Frame(PLACEHOLDER, "kernel:eval", "0", 0))
        ph2.metrics.add(kkind, "invocations", 1)
        ph2.metrics.add(kkind, "time_ns", 100.0)
        cp = cct.get_or_insert(main,
                               Frame(PLACEHOLDER, "copy:h2d", "1", 0))
        cp.metrics.add(pkind, "invocations", 1)
        cp.metrics.add(pkind, "bytes", 4096.0)
        main.metrics.add(cpu, "time_ns", 2_000.0)
        p = str(tmp / f"profile_r{r}_t0.rpro")
        write_profile(p, cct, reg,
                      {"rank": r, "thread": 0, "type": "cpu"}, [])
        paths.append(p)
        tw = TraceWriter(p.replace(".rpro", ".rtrc"),
                         {"rank": r, "thread": 0, "type": "cpu"})
        tw.append(0, 400, step.node_id)
        tw.append(400, 900, ph.node_id)
        tw.append(900, 1000, ph2.node_id)
        tw.close()
        traces.append(tw.path)
        # GPU-stream trace as Profiler.write() emits it: app-thread node
        # ids with the dispatching thread encoded (index 0 -> the ids
        # pass through numerically) and named in dispatch_profiles, so
        # aggregation converts them through the thread profile's gmap
        gw = TraceWriter(str(tmp / f"trace_r{r}_s0.rtrc"),
                         {"rank": r, "stream": 0, "type": "gpu",
                          "dispatch_profiles":
                              {"0": f"profile_r{r}_t0.rpro"}})
        gw.append(400, 700 + 50 * r, ph.node_id)
        gw.append(900, 960, ph2.node_id)
        gw.close()
        traces.append(gw.path)
    db = aggregate(paths, str(tmp / "db"), n_ranks=2, n_threads=2,
                   trace_paths=traces)
    return db


def test_viewer_top_down_golden(fixture_db, update_goldens):
    from repro.core import viewer
    out = viewer.top_down(fixture_db, "gpu_kernel/time_ns", max_depth=4)
    check_golden("viewer_top_down.txt", out, update_goldens)


def test_viewer_flat_golden(fixture_db, update_goldens):
    from repro.core import viewer
    out = viewer.flat(fixture_db, "gpu_kernel/time_ns", top=10)
    check_golden("viewer_flat.txt", out, update_goldens)


def test_viewer_bottom_up_golden(fixture_db, update_goldens):
    from repro.core import viewer
    out = viewer.bottom_up(fixture_db, "gpu_kernel/time_ns", top=5)
    check_golden("viewer_bottom_up.txt", out, update_goldens)


def test_viewer_counter_table_golden(fixture_db, update_goldens):
    from repro.core import viewer
    out = viewer.counter_table(fixture_db, top=5)
    check_golden("viewer_counter_table.txt", out, update_goldens)


def test_traceview_render_golden(fixture_db, update_goldens):
    from repro.traceview import TraceDB, render_view
    tdb = TraceDB(fixture_db.trace_db_path())
    out = render_view(tdb.line_views(), fixture_db, width=64, height=12,
                      depth=2, top=5)
    check_golden("traceview_render.txt", out, update_goldens)


def test_traceview_two_zooms_golden(fixture_db, update_goldens):
    """A zoomed window must stay stable too (different code path: window
    clipping + per-window glyph assignment)."""
    from repro.traceview import TraceDB, render_view
    tdb = TraceDB(fixture_db.trace_db_path())
    out = render_view(tdb.line_views(), fixture_db, t0=400, t1=900,
                      width=48, height=8, depth=3, top=4)
    check_golden("traceview_render_zoom.txt", out, update_goldens)


# ---------------------------------------------------------------------------
# Kernel-interior hot-loop tables (ISSUE 8; repro.core.kstruct)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def kstruct_db(tmp_path_factory):
    """Deterministic 2-rank measurement with a kernel-interior descent:
    the flash kernel's GPU_OP context carries a recovered interior
    (grid loop -> inlined scopes -> source-line ops) with fixed gpu_inst
    sample vectors — hand-built timestamps so the traceview join is
    byte-stable."""
    from repro.core.cct import GPU_FUNC, GPU_LOOP, GPU_OP
    tmp = tmp_path_factory.mktemp("kstruct_db")
    reg = default_registry()
    kkind = reg.kind("gpu_kernel")
    ikind = reg.kind("gpu_inst")
    midx = {m: i for i, m in enumerate(ikind.metrics)}

    def ivec(samples, stall, flops=0.0, nbytes=0.0):
        v = np.zeros(len(ikind.metrics))
        v[midx["samples"]] = samples
        v[midx[f"stall_{stall}"]] = samples
        v[midx["flops"]], v[midx["bytes"]] = flops, nbytes
        return v

    paths, traces = [], []
    for r in range(2):
        cct = CCT()
        main = cct.insert_path([Frame(HOST, "main", "app.py", 1)])
        step = cct.insert_path([Frame(HOST, "step", "app.py", 10)],
                               parent=main)
        ph = cct.get_or_insert(step,
                               Frame(PLACEHOLDER, "kernel:flash", "0", 0))
        ph.metrics.add(kkind, "invocations", 1)
        ph.metrics.add(kkind, "time_ns", 500.0)
        op = cct.get_or_insert(
            ph, Frame(GPU_OP, "custom-call:fa", "step", 5))
        root = cct.get_or_insert(
            op, Frame(GPU_FUNC, "flash_attention", "flash.py", 36))
        loop = cct.get_or_insert(
            root, Frame(GPU_LOOP, "grid:kv_blocks", "flash.py", 36))
        blk = cct.get_or_insert(
            loop, Frame(GPU_FUNC, "_block", "flash.py", 63))
        init = cct.get_or_insert(
            loop, Frame(GPU_FUNC, "_init", "flash.py", 44))
        cct.get_or_insert(
            blk, Frame(GPU_OP, "dot_general", "flash.py", 67)) \
            .metrics.add_vec(ikind, ivec(20 + 4 * r, "compute", 2.1e9))
        cct.get_or_insert(
            blk, Frame(GPU_OP, "exp", "flash.py", 80)) \
            .metrics.add_vec(ikind, ivec(5, "compute", 1.8e8))
        cct.get_or_insert(
            init, Frame(GPU_OP, "swap", "flash.py", 47)) \
            .metrics.add_vec(ikind, ivec(8 + r, "memory", 0.0, 3.3e7))
        p = str(tmp / f"profile_r{r}_t0.rpro")
        write_profile(p, cct, reg,
                      {"rank": r, "thread": 0, "type": "cpu"}, [])
        paths.append(p)
        tw = TraceWriter(p.replace(".rpro", ".rtrc"),
                         {"rank": r, "thread": 0, "type": "cpu"})
        tw.append(0, 1000, step.node_id)
        tw.close()
        traces.append(tw.path)
        gw = TraceWriter(str(tmp / f"trace_r{r}_s0.rtrc"),
                         {"rank": r, "stream": 0, "type": "gpu",
                          "dispatch_profiles":
                              {"0": f"profile_r{r}_t0.rpro"}})
        gw.append(200, 700, ph.node_id)
        gw.close()
        traces.append(gw.path)
    return aggregate(paths, str(tmp / "db"), n_ranks=2, n_threads=1,
                     trace_paths=traces)


def test_viewer_top_hot_loops_golden(kstruct_db, update_goldens):
    from repro.core import viewer
    out = viewer.top_hot_loops(kstruct_db, top=10)
    check_golden("viewer_top_hot_loops.txt", out, update_goldens)


def test_traceview_top_hot_loops_golden(kstruct_db, update_goldens):
    from repro.traceview import TraceDB
    from repro.traceview.stats import top_hot_loops
    tdb = TraceDB(kstruct_db.trace_db_path())
    rows = top_hot_loops(tdb.line_views(), kstruct_db, k=10)
    out = "\n".join(
        f"{kern:<16} {loop:<14} {line:<12} {op:<12} "
        f"{samples:7.0f} {busy:12.1f}"
        for kern, loop, line, op, samples, busy in rows)
    check_golden("traceview_top_hot_loops.txt", out, update_goldens)
