"""Numerical equivalence tests between model compute paths:
chunked/binary/flash attention vs naive softmax; ssd chunked vs sequential;
mlstm chunked vs recurrent; prefill+decode vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import attention_ref, mlstm_ref, ssm_scan_ref
from repro.models import attention as A
from repro.models import ssm, xlstm
from repro.models import transformer as T
from repro.configs import get_config

KEY = jax.random.PRNGKey(42)


def qkv(B=2, S=128, H=4, Hkv=2, D=32):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, S, H, D)),
            jax.random.normal(ks[1], (B, S, Hkv, D)),
            jax.random.normal(ks[2], (B, S, Hkv, D)))


def test_chunked_attention_matches_ref():
    q, k, v = qkv()
    out = A.chunked_attention(q, k, v, q_chunk=32, kv_chunk=32)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_binary_schedule_matches_dense():
    q, k, v = qkv(S=256)
    dense = A.chunked_attention(q, k, v, q_chunk=32, kv_chunk=32,
                                schedule="dense")
    binary = A.chunked_attention(q, k, v, q_chunk=32, kv_chunk=32,
                                 schedule="binary")
    np.testing.assert_allclose(np.asarray(binary), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_binary_schedule_grads_match():
    q, k, v = qkv(S=128, H=2, Hkv=2)
    def loss(sched):
        return lambda q_, k_, v_: (A.chunked_attention(
            q_, k_, v_, q_chunk=32, kv_chunk=32, schedule=sched) ** 2).sum()
    gd = jax.grad(loss("dense"), argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(loss("binary"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gd, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_swa_matches_ref_window():
    q, k, v = qkv(S=256, H=4, Hkv=4)
    w = 64
    out = A.swa_attention(q, k, v, w)
    want = attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_chunked_window_matches_ref():
    q, k, v = qkv(S=256)
    w = 96  # not a multiple of chunk
    out = A.chunked_attention(q, k, v, q_chunk=64, kv_chunk=64, window=w)
    want = attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_last_row():
    q, k, v = qkv(S=64, H=4, Hkv=2)
    full = attention_ref(q, k, v, causal=True)
    out = A.decode_attention(q[:, -1], k, v, length=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_ssd_chunked_matches_sequential():
    ks = jax.random.split(KEY, 5)
    B, S, nh, hd, st = 2, 128, 2, 16, 8
    xv = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    ld = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    Bm = jax.random.normal(ks[2], (B, S, st)) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, st)) * 0.3
    h0 = jax.random.normal(ks[4], (B, nh, hd, st)) * 0.1
    y, h = ssm.ssd_chunked(xv, ld, Bm, Cm, chunk=32, h0=h0)
    yr, hr = ssm_scan_ref(xv, ld, Bm, Cm, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


def test_mamba_decode_consistent_with_prefill():
    """Running S steps of recurrent decode == chunked prefill."""
    ks = jax.random.split(KEY, 2)
    d, nh, hd, st = 32, 2, 8, 8
    p = ssm.init_ssm_params(ks[0], d, nh, hd, st, jnp.float32)
    x = jax.random.normal(ks[1], (1, 16, d)) * 0.3
    y_par, (h_par, conv_par) = ssm.mamba_forward(
        p, x, n_heads=nh, head_dim=hd, state=st, chunk=8)
    # recurrent: feed one token at a time
    h = jnp.zeros((1, nh, hd, st), jnp.float32)
    conv = jnp.zeros((1, ssm.CONV_W - 1, nh * hd), jnp.float32)
    ys = []
    for t in range(16):
        y_t, (h, conv) = ssm.mamba_forward(
            p, x[:, t:t + 1], n_heads=nh, head_dim=hd, state=st,
            ssm_state=h, conv_state=conv)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_par),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_chunked_matches_recurrent():
    ks = jax.random.split(KEY, 5)
    B, S, nh, dqk, dv = 1, 64, 2, 8, 16
    q = jax.random.normal(ks[0], (B, S, nh, dqk))
    k = jax.random.normal(ks[1], (B, S, nh, dqk))
    v = jax.random.normal(ks[2], (B, S, nh, dv))
    ig = jax.random.normal(ks[3], (B, S, nh))
    fg = jax.random.normal(ks[4], (B, S, nh)) + 2.0
    h_par, (H_par, m_par) = xlstm.mlstm_chunked(q, k, v, ig, fg, chunk=16)
    h_seq, (H_seq, m_seq) = mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(H_par), np.asarray(H_seq),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "hymba-1.5b", "xlstm-125m",
                                  "granite-moe-1b-a400m"])
def test_prefill_then_decode_matches_forward(arch):
    """logits(prefill(x[:t]) + decode x[t]) == logits(forward(x[:t+1]))."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # capacity drops legitimately differ between a 16-token and a
        # 17-token dispatch; disable drops for the consistency check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    S = 16
    opts = T.ModelOptions(q_chunk=8, kv_chunk=8, ssm_chunk=4, loss_chunk=8)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0,
                                cfg.vocab)
    # full forward logits at position S (predicting S+1)
    hidden, _ = T.forward(params, cfg, tokens, opts=opts)
    from repro.models.layers import rms_norm
    h_last = rms_norm(hidden[:, -1], params["final_norm"])
    want = (h_last @ params["unembed"]).astype(jnp.float32)
    # prefill on S tokens, grow cache to S+1 slots (as the serve driver
    # does), decode token S
    from repro.launch.serve import _grow_cache
    _, cache = T.prefill(params, cfg, tokens[:, :S], opts=opts)
    cache = _grow_cache(cfg, cache, 1, S + 1, S)
    got, _ = T.decode_step(params, cfg, cache, token=tokens[:, S],
                           pos=jnp.int32(S), opts=opts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_loss_label_masking():
    cfg = get_config("qwen2-1.5b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opts = T.ModelOptions(q_chunk=8, kv_chunk=8, loss_chunk=8)
    tokens = jnp.ones((1, 16), jnp.int32)
    all_masked = {"tokens": tokens,
                  "labels": jnp.full((1, 16), -100, jnp.int32)}
    loss, metrics = T.loss_fn(params, cfg, all_masked, opts=opts)
    assert float(metrics["ntok"]) == 0
    assert float(metrics["nll"]) == 0.0
