"""hpcstruct analogue: HLO parsing, scope/loop/inline recovery, trip-count
cost correction (paper §5)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.structure import collective_bytes, parse_hlo, parse_shape


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def xla_cost(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a list of per-computation
    dicts on jax 0.4.x and a flat dict on newer versions."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca


def test_parse_shape():
    assert parse_shape("f32[4,8]") == (32, 128)
    assert parse_shape("(f32[2], bf16[3,3])") == (2 + 9, 8 + 18)
    assert parse_shape("pred[]") == (1, 1)  # scalar: dims empty
    assert parse_shape("token[]") == (0, 0)


def test_scan_trip_count_and_cost_scale():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.ones((64, 64))
    c = jax.jit(f).lower(x).compile()
    mod = parse_hlo(c.as_text())
    whiles = [op for op in mod.all_ops() if op.opcode == "while"]
    assert whiles and whiles[0].trip_count == 10
    fr, _ = mod.cost_scale()
    xla_flops = xla_cost(c)["flops"]
    assert xla_flops * fr == pytest.approx(10 * 2 * 64 ** 3, rel=0.05)


def test_nested_scan_multipliers():
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = jax.jit(f).lower(jnp.ones((32, 32))).compile()
    mod = parse_hlo(c.as_text())
    fr, _ = mod.cost_scale()
    want = 15 * 2 * 32 ** 3
    assert xla_cost(c)["flops"] * fr == pytest.approx(want, rel=0.05)


def test_op_context_has_scopes_and_loops():
    def f(x):
        with jax.named_scope("outer_scope"):
            def body(c, _):
                with jax.named_scope("inner"):
                    return jnp.tanh(c @ c), None
            y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    mod = parse_hlo(compiled_text(f, jnp.ones((16, 16))))
    dots = [o for o in mod.all_ops() if o.opcode == "dot"]
    assert dots
    ctx = mod.op_context(dots[0])
    kinds = [fr.kind for fr in ctx]
    assert "gpu_loop" in kinds, f"while loop must appear in context: {ctx}"
    names = " / ".join(fr.name for fr in ctx)
    assert "outer_scope" in names
    assert ctx[-1].kind == "gpu_op"


def test_stack_frames_parsed():
    def g(x):
        return jnp.sin(x) * 2

    def f(x):
        return g(x) + 1

    txt = compiled_text(f, jnp.ones((8,)))
    if "stack_frames" not in txt.lower():
        pytest.skip("this jax/platform emits no StackFrames table in "
                    "compiled HLO text")
    mod = parse_hlo(txt)
    assert mod.frames, "StackFrames table must parse"
    chains = [mod.frame_chain(fid) for fid in mod.frames]
    fns = {fr.name for ch in chains for fr in ch}   # frame_chain -> cct.Frame
    assert any("g" in fn for fn in fns)


def test_call_graph_edges():
    def f(x):
        def body(c, _):
            return c * 2, None
        y, _ = jax.lax.scan(body, x, None, length=3)
        return y

    mod = parse_hlo(compiled_text(f, jnp.ones((8, 8))))
    nodes, edges = mod.call_graph()
    assert mod.entry in nodes
    callees = {b for (a, b) in edges if a == mod.entry}
    assert callees, "entry must call while body/cond computations"


def test_dot_flops_estimate():
    mod = parse_hlo(compiled_text(lambda a, b: a @ b,
                                  jnp.ones((32, 64)), jnp.ones((64, 16))))
    dots = [o for o in mod.all_ops() if o.opcode == "dot"]
    assert dots
    assert dots[0].flops == pytest.approx(2 * 32 * 64 * 16, rel=0.01)


def test_collective_bytes_parse_synthetic():
    """Collective parsing incl. trip-count weighting on hand-written HLO."""
    hlo = """HloModule synth

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128]{0} get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128])) -> pred[] {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[128]) -> (s32[], f32[128]) {
  %x = f32[128]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128]) tuple(%zero, %x)
  %ag = f32[512]{0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""
    mod = parse_hlo(hlo)
    coll = collective_bytes(mod)
    # all-gather outside the loop: operand 128*4 = 512B, wire (g-1)*512
    # all-reduce inside: 512B * 7 trips, wire 2*(3/4)*512*7
    assert coll["operand_bytes"] == pytest.approx(512 + 512 * 7)
    assert coll["wire_bytes"] == pytest.approx(
        3 * 512 + 2 * 0.75 * 512 * 7)
    assert coll["operand_bytes/all-reduce"] == pytest.approx(512 * 7)
    mults = mod.comp_multipliers()
    assert mults["body"] == 7


def test_fusion_cost_attribution():
    """Fused computations: flops counted via callee, bytes at the boundary."""
    def f(x):
        return jnp.tanh(x * 2 + 1).sum()

    mod = parse_hlo(compiled_text(f, jnp.ones((256, 256))))
    t = mod.total_costs()
    assert t["flops_once"] > 0
    assert t["bytes_once"] > 0
    # no loops here: scaled == once, up to O(1) flops from scalar callee
    # computations (e.g. reduce's `add`) that some jax versions share
    # across call sites (counted once by XLA, per-site by our multiplier)
    assert t["flops_scaled"] == pytest.approx(t["flops_once"], rel=1e-4)


# -- collective opcode classification (ISSUE 8 satellite) -----------------
def _op(opcode):
    from repro.core.structure import HloOp
    return HloOp(name="x", opcode=opcode, comp="main", type_str="f32[128]",
                 out_elems=128, out_bytes=512, operands=("a",))


@pytest.mark.parametrize("base", ["all-reduce", "all-gather",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute"])
@pytest.mark.parametrize("suffix", ["", "-start", "-done"])
def test_collective_kind_all_spellings(base, suffix):
    """Regression (ISSUE 8): ``rstrip("-start")`` strips a character
    *set*, so "reduce-scatter" lost its trailing "r" and every async
    spelling of it (and of all-to-all/collective-permute, which end in
    rstrip-set characters too) was misclassified.  Proper suffix
    handling must recognize every sync/async spelling."""
    op = _op(base + suffix)
    assert op.is_collective
    assert op.collective_kind == base


@pytest.mark.parametrize("opcode", ["add", "custom-call", "all-reduce-scat",
                                    "start", "done", "reduce",
                                    "scatter", "gather"])
def test_collective_kind_rejects_non_collectives(opcode):
    op = _op(opcode)
    assert not op.is_collective
    assert op.collective_kind == ""


def test_async_collective_start_done_counted_once():
    """The -start half carries the payload; the -done completion is
    collective (for stall classification) but contributes no bytes —
    otherwise every async collective would double-count."""
    hlo = """HloModule asynccoll

ENTRY %main (x: f32[128]) -> f32[512] {
  %x = f32[128]{0} parameter(0)
  %rs = f32[32]{0} reduce-scatter-start(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %rsd = f32[32]{0} reduce-scatter-done(%rs)
  %ag = f32[512]{0} all-gather-start(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %agd = f32[512]{0} all-gather-done(%ag)
}
"""
    mod = parse_hlo(hlo)
    by_kind = {}
    for op in mod.collective_ops():
        by_kind.setdefault(op.collective_kind, []).append(op.opcode)
    # initiation halves only — one op per kind, no -done double count
    assert by_kind == {"reduce-scatter": ["reduce-scatter-start"],
                       "all-gather": ["all-gather-start"]}
    coll = collective_bytes(mod)
    assert coll["operand_bytes/reduce-scatter"] == pytest.approx(512)
    assert coll["operand_bytes/all-gather"] == pytest.approx(512)
    assert coll["operand_bytes"] == pytest.approx(1024)
    # the -done ops are still *classified* collective for stall blame
    dones = [op for op in mod.all_ops() if op.opcode.endswith("-done")]
    assert len(dones) == 2 and all(op.is_collective for op in dones)
