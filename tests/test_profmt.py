"""Profile file format roundtrip (paper §4.6 Fig. 3b) + CCT + metrics."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.cct import CCT, Frame, HOST, PLACEHOLDER, unwind_host_stack
from repro.core.metrics import default_registry
from repro.core.profmt import (dense_profile_nbytes, read_profile,
                               write_profile)


def build_cct(rng, registry, n_paths=10, depth=4):
    cct = CCT()
    kinds = registry.kinds
    for _ in range(n_paths):
        frames = [Frame(HOST, f"f{rng.integers(5)}", f"m{rng.integers(3)}.py",
                        int(rng.integers(100)))
                  for _ in range(int(rng.integers(1, depth)))]
        node = cct.insert_path(frames)
        k = kinds[int(rng.integers(len(kinds)))]
        m = k.metrics[int(rng.integers(len(k.metrics)))]
        node.metrics.add(k, m, float(rng.integers(1, 50)))
    return cct


def test_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    reg = default_registry()
    cct = build_cct(rng, reg)
    ident = {"host": "h0", "rank": 3, "thread": 1, "type": "cpu"}
    path = str(tmp_path / "p.rpro")
    sizes = write_profile(path, cct, reg, ident, ["mod_a"])
    prof = read_profile(path)
    assert prof.identity == ident
    assert prof.load_modules == ["mod_a"]
    assert prof.metrics == reg.metric_names
    assert len(prof.node_ids) == cct.n_nodes
    # every node's metrics survive
    by_id = cct.node_by_id()
    for nid in prof.node_ids:
        want = dict(by_id[int(nid)].metrics.nonzero_items(reg))
        assert prof.node_values(int(nid)) == pytest.approx(want)


def test_parents_precede_children(tmp_path):
    """The aggregator relies on creation order being topological."""
    rng = np.random.default_rng(1)
    reg = default_registry()
    cct = build_cct(rng, reg, n_paths=30)
    path = str(tmp_path / "p.rpro")
    write_profile(path, cct, reg, {}, [])
    prof = read_profile(path)
    seen = set()
    pos = {int(n): i for i, n in enumerate(prof.node_ids)}
    for nid, par in zip(prof.node_ids, prof.parents):
        if par >= 0:
            assert pos[int(par)] < pos[int(nid)]


def test_sparse_only_nonzero(tmp_path):
    """Fig. 3b: only non-zero metric values are stored."""
    reg = default_registry()
    cct = CCT()
    n = cct.insert_path([Frame(HOST, "f", "m.py", 1)])
    n.metrics.add(reg.kind("cpu"), "time_ns", 5.0)
    big = cct.insert_path([Frame(HOST, "g", "m.py", 2)])  # no metrics
    path = str(tmp_path / "p.rpro")
    write_profile(path, cct, reg, {}, [])
    prof = read_profile(path)
    assert len(prof.values) == 1
    assert prof.node_values(big.node_id) == {}
    # dense expansion would cost n_nodes x n_metrics x 8
    assert dense_profile_nbytes(cct.n_nodes, reg.n_metrics) == \
        cct.n_nodes * reg.n_metrics * 8


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_roundtrip_property(tmp_path_factory, seed, n_paths):
    tmp = tmp_path_factory.mktemp("prof")
    rng = np.random.default_rng(seed)
    reg = default_registry()
    cct = build_cct(rng, reg, n_paths=n_paths)
    path = str(tmp / "p.rpro")
    write_profile(path, cct, reg, {"rank": 0}, [])
    prof = read_profile(path)
    total_written = sum(
        v for n in cct.nodes() for _, v in n.metrics.nonzero_items(reg))
    assert float(prof.values.sum()) == pytest.approx(total_written)


def test_unwind_host_stack_prunes_tool_frames():
    def inner():
        return unwind_host_stack()
    frames = inner()
    assert frames, "must capture the test frame"
    assert all("repro/core" not in f.module for f in frames)
    assert frames[-1].name == "inner"


def test_cct_dedup():
    cct = CCT()
    f = [Frame(HOST, "a", "x.py", 1), Frame(HOST, "b", "x.py", 2)]
    n1 = cct.insert_path(f)
    n2 = cct.insert_path(f)
    assert n1 is n2
    assert cct.n_nodes == 3  # root + a + b
