"""Merge-time retention policies (ISSUE 5 tentpole: windowed databases,
dedup/compaction for continuous profiling).

The pinned contract: **retiring epochs through a RetentionPolicy is
byte-identical to re-aggregating the surviving profile set from
scratch** (stats, cms, pms, trace.db, meta — the database never betrays
that it once held more), and dedup is idempotent.
"""
import os

import numpy as np
import pytest

from repro.core.aggregate import aggregate
from repro.core.cct import CCT, Frame, HOST, PLACEHOLDER
from repro.core.merge import main as merge_main, merge_databases
from repro.core.metrics import default_registry
from repro.core.profmt import write_profile
from repro.core.retention import (RetentionPolicy, apply_retention,
                                  epoch_key, parse_retention)
from repro.core.trace import TraceWriter
from test_merge import assert_db_identical, db_bytes, traces_of


# ---------------------------------------------------------------------------
# Fixtures: tagged epochs of a continuously-profiled 2-rank job
# ---------------------------------------------------------------------------
def write_epoch(tmp_path, epoch, n_ranks=2, scale=1.0):
    """One epoch's measurement: per rank a profile + aligned trace, both
    stamped with the epoch tag (what ``Profiler(tag=...)`` produces)."""
    reg = default_registry()
    paths = []
    for r in range(n_ranks):
        cct = CCT()
        main = cct.insert_path([Frame(HOST, "main", "app.py", 1)])
        step = cct.insert_path(
            [Frame(HOST, f"step_e{epoch}", "app.py", 10 + epoch)],
            parent=main)
        ph = cct.get_or_insert(step, Frame(PLACEHOLDER, "kernel:train",
                                           "0", 0))
        ph.metrics.add(reg.kind("gpu_kernel"), "invocations", 1.0 + r)
        ph.metrics.add(reg.kind("gpu_kernel"), "time_ns",
                       scale * 100.0 * (r + 1) * epoch)
        main.metrics.add(reg.kind("cpu"), "time_ns", 1000.0 * epoch)
        ident = {"rank": r, "thread": 0, "type": "cpu",
                 "tag": f"epoch{epoch}"}
        p = str(tmp_path / f"profile_epoch{epoch}_r{r}_t0.rpro")
        write_profile(p, cct, reg, ident, [])
        tw = TraceWriter(p.replace(".rpro", ".rtrc"), ident)
        tw.append(1000 * epoch, 1000 * epoch + 50, step.node_id)
        tw.append(1000 * epoch + 50, 1000 * epoch + 80, ph.node_id)
        tw.close()
        paths.append(p)
    return paths


def build_epochs(tmp_path, epochs):
    by_epoch = {e: write_epoch(tmp_path, e) for e in epochs}
    merged = str(tmp_path / "db_all")
    all_paths = [p for e in epochs for p in by_epoch[e]]
    aggregate(all_paths, merged, trace_paths=traces_of(all_paths))
    return by_epoch, merged


def expect_db(tmp_path, name, paths):
    out = str(tmp_path / name)
    aggregate(paths, out, trace_paths=traces_of(paths))
    return out


# ---------------------------------------------------------------------------
# Policy parsing + epoch ordering
# ---------------------------------------------------------------------------
def test_parse_retention_specs():
    p = parse_retention("last=2,max=64,dedup,since=epoch3")
    assert p == RetentionPolicy(keep_last_epochs=2, since_epoch="epoch3",
                                max_profiles=64, dedup=True)
    assert parse_retention("dedup").dedup
    assert parse_retention("last=1") == RetentionPolicy(keep_last_epochs=1)
    for bad in ("keep=2", "last", "dedup=yes", "last=x"):
        with pytest.raises(ValueError):
            parse_retention(bad)
    with pytest.raises(ValueError, match=">= 1"):
        RetentionPolicy(max_profiles=0)
    assert RetentionPolicy().is_noop
    assert not RetentionPolicy(dedup=True).is_noop


def test_epoch_key_natural_order():
    tags = ["epoch10", "epoch2", "epoch1"]
    assert sorted(tags, key=epoch_key) == ["epoch1", "epoch2", "epoch10"]
    assert epoch_key("e2s3") < epoch_key("e2s10")


# ---------------------------------------------------------------------------
# The pinned contract: retire epochs == re-aggregate the survivors
# ---------------------------------------------------------------------------
def test_keep_last_epochs_equals_reaggregation(tmp_path):
    by_epoch, merged = build_epochs(tmp_path, [1, 2, 3])
    out = str(tmp_path / "retained")
    db = merge_databases([merged], out,
                         retention=RetentionPolicy(keep_last_epochs=2))
    want = expect_db(tmp_path, "want", by_epoch[2] + by_epoch[3])
    assert_db_identical(out, want)
    tags = {v.get("tag") for v in db.profile_ids.values()}
    assert tags == {"epoch2", "epoch3"}


def test_since_epoch_window_equals_reaggregation(tmp_path):
    by_epoch, merged = build_epochs(tmp_path, [1, 2, 3])
    out = str(tmp_path / "since")
    merge_databases([merged], out,
                    retention=RetentionPolicy(since_epoch="epoch2"))
    want = expect_db(tmp_path, "want", by_epoch[2] + by_epoch[3])
    assert_db_identical(out, want)


def test_epochs_retire_in_natural_order(tmp_path):
    """epoch10 is newer than epoch2 (no lexicographic trap)."""
    by_epoch, merged = build_epochs(tmp_path, [2, 10])
    out = str(tmp_path / "nat")
    db = merge_databases([merged], out,
                         retention=RetentionPolicy(keep_last_epochs=1))
    assert {v["tag"] for v in db.profile_ids.values()} == {"epoch10"}
    assert_db_identical(out, expect_db(tmp_path, "want", by_epoch[10]))


def test_max_profiles_retires_whole_oldest_epochs(tmp_path):
    by_epoch, merged = build_epochs(tmp_path, [1, 2, 3])   # 6 profiles
    out = str(tmp_path / "capped")
    db = merge_databases([merged], out,
                         retention=RetentionPolicy(max_profiles=4))
    assert len(db.profile_ids) == 4
    assert_db_identical(out, expect_db(tmp_path, "want",
                                       by_epoch[2] + by_epoch[3]))


def test_max_profiles_caps_within_single_epoch(tmp_path):
    paths = write_epoch(tmp_path, 1, n_ranks=4)
    merged = str(tmp_path / "db")
    aggregate(paths, merged, trace_paths=traces_of(paths))
    out = str(tmp_path / "capped")
    db = merge_databases([merged], out,
                         retention=RetentionPolicy(max_profiles=2))
    # canonically-first (lowest rank) profiles drop, and their trace
    # lines go with them (sub-epoch trace compaction): the capped
    # database is byte-identical to re-aggregating the survivors
    assert len(db.profile_ids) == 2
    assert {v["rank"] for v in db.profile_ids.values()} == {2, 3}
    assert_db_identical(out, expect_db(tmp_path, "want", paths[2:]))


def test_single_epoch_cap_keeps_unmatched_trace_lines(tmp_path):
    """A trace line whose identity matches no profile (a trace-only
    stream) survives the sub-epoch cap — compaction only drops lines
    orphaned by a dropped profile."""
    from repro.core.merge import TraceData
    paths = write_epoch(tmp_path, 1, n_ranks=3)
    entries, _, _ = _entries_of(tmp_path, paths)
    lines = [TraceData(dict(e[0]), np.array([0]), np.array([10]),
                       np.array([1])) for e in entries]
    lines.append(TraceData({"stream": "gpu0"}, np.array([0]),
                           np.array([10]), np.array([1])))
    items, kept, rep = apply_retention(entries, lines,
                                       RetentionPolicy(max_profiles=1))
    assert len(items) == 1
    kept_ids = [td.identity for td in kept]
    assert {"stream": "gpu0"} in kept_ids          # unmatched: kept
    assert items[0][0] in kept_ids                 # survivor's line: kept
    assert len(kept) == 2 and rep.dropped_lines == 2


def test_untagged_profiles_survive_epoch_policies(tmp_path):
    from test_aggregate_equiv import synth_inputs
    untagged, _ = synth_inputs(tmp_path, seed=70, n_profiles=2,
                               with_traces=False)
    tagged = write_epoch(tmp_path, 1)
    merged = str(tmp_path / "db")
    aggregate(untagged + tagged, merged, trace_paths=traces_of(tagged))
    out = str(tmp_path / "out")
    db = merge_databases([merged], out,
                         retention=RetentionPolicy(since_epoch="epoch9"))
    assert len(db.profile_ids) == 2
    assert all("tag" not in v for v in db.profile_ids.values())


# ---------------------------------------------------------------------------
# Dedup / compaction
# ---------------------------------------------------------------------------
def test_dedup_is_idempotent_and_collapses_self_merge(tmp_path):
    paths = write_epoch(tmp_path, 1)
    a = str(tmp_path / "a")
    aggregate(paths, a, trace_paths=traces_of(paths))
    dd = RetentionPolicy(dedup=True)
    once = str(tmp_path / "once")
    merge_databases([a, a], once, retention=dd)       # multiset doubled...
    assert_db_identical(once, a)                      # ...dedup restores a
    twice = str(tmp_path / "twice")
    merge_databases([once], twice, retention=dd)      # idempotent
    assert_db_identical(twice, once)


def test_dedup_keeps_canonically_first_of_identical_identities(tmp_path):
    (tmp_path / "m1").mkdir()
    (tmp_path / "m2").mkdir()
    e1 = write_epoch(tmp_path / "m1", 1)
    e1b = write_epoch(tmp_path / "m2", 1, scale=7.0)  # same identities!
    (entries_in, lines, report) = _entries_of(tmp_path, e1 + e1b)
    items, _, rep = apply_retention(entries_in, [],
                                    RetentionPolicy(dedup=True))
    assert rep.deduped_profiles == 2
    assert len(items) == 2


def _entries_of(tmp_path, paths):
    db_dir = str(tmp_path / "entries_db")
    aggregate(paths, db_dir)
    from repro.core.merge import LoadedShard
    sh = LoadedShard(db_dir)
    entries = [(sh.identities[int(pv.profile_id)],
                pv.ctx.astype(np.int64), pv.metric.astype(np.int64),
                pv.values, sh.coverage[int(pv.profile_id)])
               for pv in sh.pvals]
    return entries, [], None


def test_retired_contexts_leave_no_trace_in_meta(tmp_path):
    """The whole point of coverage: a context only ever touched by a
    retired epoch is gone from the retained tree."""
    by_epoch, merged = build_epochs(tmp_path, [1, 2])
    out = str(tmp_path / "r")
    db = merge_databases([merged], out,
                         retention=RetentionPolicy(keep_last_epochs=1))
    names = {f.name for f in db.frames}
    assert "step_e2" in names and "step_e1" not in names


# ---------------------------------------------------------------------------
# Wiring: aggregate(retention=...), incremental epochs, CLI
# ---------------------------------------------------------------------------
def test_aggregate_retention_one_shot(tmp_path):
    by_epoch = {e: write_epoch(tmp_path, e) for e in (1, 2, 3)}
    all_paths = [p for e in (1, 2, 3) for p in by_epoch[e]]
    out = str(tmp_path / "db")
    aggregate(all_paths, out, trace_paths=traces_of(all_paths),
              retention=RetentionPolicy(keep_last_epochs=1), workers=2,
              driver="thread")
    assert_db_identical(out, expect_db(tmp_path, "want", by_epoch[3]))


def test_continuous_profiling_loop_with_retention_window(tmp_path):
    """The production shape: each epoch extends the database in place with
    ``base_db`` + a keep-last-2 window; at every step the database is
    byte-identical to re-aggregating the two newest epochs."""
    by_epoch = {e: write_epoch(tmp_path, e) for e in (1, 2, 3, 4)}
    db_dir = str(tmp_path / "db")
    policy = RetentionPolicy(keep_last_epochs=2)
    aggregate(by_epoch[1], db_dir, trace_paths=traces_of(by_epoch[1]))
    for e in (2, 3, 4):
        aggregate(by_epoch[e], db_dir, base_db=db_dir,
                  trace_paths=traces_of(by_epoch[e]), retention=policy)
        survivors = [p for ee in (max(1, e - 1), e) for p in by_epoch[ee]]
        want = expect_db(tmp_path, f"want{e}", survivors)
        assert_db_identical(db_dir, want)


def test_merge_cli_retain_flag(tmp_path, capsys):
    by_epoch, merged = build_epochs(tmp_path, [1, 2, 3])
    out = str(tmp_path / "out")
    rc = merge_main([merged, "-o", out, "--retain", "last=1"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "retention: kept 2 profile(s)" in text
    assert "epochs retired: epoch1 epoch2" in text
    assert "profiles: 2" in text


def test_aggregate_cli_retain_flag(tmp_path, capsys):
    from repro.core.pipeline.cli import main as cli_main
    (tmp_path / "m").mkdir()
    for e in (1, 2):
        write_epoch(tmp_path / "m", e)
    out = str(tmp_path / "db")
    rc = cli_main([str(tmp_path / "m"), "-o", out, "--retain", "last=1"])
    assert rc == 0
    assert "profiles: 2" in capsys.readouterr().out


def test_retention_rejects_remaps_out():
    with pytest.raises(ValueError, match="remaps_out"):
        merge_databases(["x"], "y", retention=RetentionPolicy(dedup=True),
                        remaps_out=[])


def test_legacy_database_without_coverage_still_merges(tmp_path):
    """Databases written before coverage.npz existed fall back to the
    ancestor closure of their nonzero ctxs."""
    paths = write_epoch(tmp_path, 1)
    a = str(tmp_path / "a")
    aggregate(paths, a, trace_paths=traces_of(paths))
    os.remove(os.path.join(a, "coverage.npz"))
    out = str(tmp_path / "out")
    db = merge_databases([a], out,
                         retention=RetentionPolicy(keep_last_epochs=1))
    assert len(db.profile_ids) == 2
    assert db_bytes(out)["stats.npz"] == db_bytes(a)["stats.npz"]


def test_retention_report_summary():
    entries, lines, _ = [], [], None
    items, lns, rep = apply_retention(entries, lines,
                                      RetentionPolicy(dedup=True))
    assert items == [] and lns == []
    assert rep.summary().startswith("retention: kept 0 profile(s)")
