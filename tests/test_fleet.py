"""Fleet aggregation (ISSUE 6 tentpole): envelopes, journal,
daemon ingest, producer client.

The pinned contract: the fleet database is **byte-identical to a
one-shot ``aggregate()`` over the union of journaled shards**, and
ingest is exactly-once — duplicates are no-ops, torn/corrupt/
conflicting/mismatched deliveries quarantine with a reason, and
nothing the transport does can make a shard fold twice
(tests/test_fleet_crash.py adds the crash schedules).
"""
import json
import os
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.core.aggregate import aggregate
from repro.core.cct import CCT, Frame, HOST
from repro.core.metrics import MetricRegistry, default_registry
from repro.core.profmt import write_profile
from repro.core.retention import RetentionPolicy
from repro.core.trace import TraceWriter
from repro.fleet import (DirectoryTransport, EnvelopeError, FleetDaemon,
                         Journal, ShardProducer, SocketIngest,
                         SocketTransport, TransportError, pack_envelope,
                         unpack_envelope, verify_envelope)
from repro.fleet.client import DeliveryReport
from repro.fleet.journal import JOURNAL_NAME
from repro.ft.watchdog import RestartPolicy
from test_merge import DB_FILES, assert_db_identical, db_bytes


@pytest.fixture(autouse=True)
def _scrub_inject_env(monkeypatch):
    """The CI chaos job exports REPRO_FAULT_POINTS=all; keep it from
    self-arming the in-process CLI calls (``arm_from_env``) here — only
    the crash tests inject faults, explicitly."""
    from repro.ft import inject
    monkeypatch.delenv(inject.ENV_POINTS, raising=False)
    monkeypatch.delenv(inject.ENV_MODE, raising=False)
    yield
    inject.clear()


# ---------------------------------------------------------------------------
# Fixtures: per-host shards with disjoint ranks (a real fleet's shape)
# ---------------------------------------------------------------------------
def synth_shard_inputs(d, seed, rank_base, n_profiles=3):
    """Profiles + traces for one producer host (ranks are globally
    unique across hosts, as they are in a real job)."""
    d = Path(d)
    d.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(seed)
    reg = default_registry()
    cpu = reg.kind("cpu")
    paths, traces = [], []
    for p in range(n_profiles):
        rank = rank_base + p
        cct = CCT()
        nodes = []
        for _ in range(int(rng.integers(15, 40))):
            depth = 1 + int(rng.integers(4))
            frames = [Frame(HOST, f"fn{rng.integers(10)}",
                            f"file{rng.integers(3)}.py",
                            int(rng.integers(30)))
                      for _ in range(depth)]
            node = cct.insert_path(frames)
            node.metrics.add(cpu, "time_ns",
                             float(rng.integers(1, 10_000)))
            nodes.append(node)
        path = str(d / f"r{rank}.rpro")
        write_profile(path, cct, reg, {"rank": rank, "type": "cpu"}, [])
        paths.append(path)
        tw = TraceWriter(path.replace(".rpro", ".rtrc"), {"rank": rank})
        t = 0
        for node in nodes[:8]:
            tw.append(t, t + 10, node.node_id)
            t += 10
        tw.close()
        traces.append(tw.path)
    return paths, traces


def build_shard(tmp_path, i, *, n_profiles=3):
    """One producer's shard database + its raw inputs."""
    paths, traces = synth_shard_inputs(tmp_path / f"m{i}", 100 + i,
                                       10 * i, n_profiles)
    db = str(tmp_path / f"shard{i}")
    aggregate(paths, db, trace_paths=traces)
    return db, paths, traces


def build_fleet_inputs(tmp_path, n_shards=3):
    shard_dbs, all_paths, all_traces = [], [], []
    for i in range(n_shards):
        db, paths, traces = build_shard(tmp_path, i)
        shard_dbs.append(db)
        all_paths += paths
        all_traces += traces
    ref = str(tmp_path / "ref")
    aggregate(all_paths, ref, trace_paths=all_traces)
    return shard_dbs, ref


def fresh_daemon(tmp_path, **kw):
    return FleetDaemon(str(tmp_path / "fleet"), str(tmp_path / "spool"),
                       n_workers=1, **kw)


def fresh_producer(tmp_path, daemon, **kw):
    kw.setdefault("sleep", lambda s: None)
    return ShardProducer(str(tmp_path / "outbox"),
                         DirectoryTransport(daemon.incoming_dir),
                         producer="hostA", **kw)


# ---------------------------------------------------------------------------
# Envelope format
# ---------------------------------------------------------------------------
def test_envelope_roundtrip_and_content_addressed_id(tmp_path):
    db, _, _ = build_shard(tmp_path, 0)
    env = str(tmp_path / "{id}.shard")
    sid = pack_envelope(db, env, producer="hostA", meta={"epoch": 3})
    path = str(tmp_path / f"{sid}.shard")
    assert os.path.exists(path) and sid.startswith("hostA-")
    header = verify_envelope(path)
    assert header.shard_id == sid and header.meta == {"epoch": 3}
    # content-addressed: identical bytes -> identical id
    assert pack_envelope(db, env, producer="hostA") == \
        pack_envelope(db, env, producer="hostA")
    out = str(tmp_path / "unpacked")
    unpack_envelope(path, out)
    assert db_bytes(out) == db_bytes(db)
    unpack_envelope(path, out)          # idempotent
    assert db_bytes(out) == db_bytes(db)


def test_envelope_detects_torn_and_corrupt(tmp_path):
    db, _, _ = build_shard(tmp_path, 0)
    path = str(tmp_path / "e.shard")
    pack_envelope(db, path, shard_id="x")
    data = Path(path).read_bytes()
    torn = tmp_path / "torn.shard"
    torn.write_bytes(data[:-5])
    with pytest.raises(EnvelopeError, match="torn"):
        verify_envelope(str(torn))
    flipped = tmp_path / "flip.shard"
    flipped.write_bytes(data[:-5] + bytes([data[-5] ^ 0xFF]) + data[-4:])
    with pytest.raises(EnvelopeError, match="SHA-256"):
        verify_envelope(str(flipped))
    (tmp_path / "junk.shard").write_bytes(b"not an envelope at all")
    with pytest.raises(EnvelopeError, match="magic"):
        verify_envelope(str(tmp_path / "junk.shard"))
    (tmp_path / "short.shard").write_bytes(data[:10])
    with pytest.raises(EnvelopeError):
        verify_envelope(str(tmp_path / "short.shard"))


def test_envelope_rejects_path_escape(tmp_path):
    db, _, _ = build_shard(tmp_path, 0)
    path = str(tmp_path / "e.shard")
    pack_envelope(db, path, shard_id="x")
    from repro.fleet.envelope import MAGIC, _HLEN
    data = Path(path).read_bytes()
    hlen = _HLEN.unpack(data[len(MAGIC):len(MAGIC) + 8])[0]
    hdr = json.loads(data[len(MAGIC) + 8:len(MAGIC) + 8 + hlen])
    hdr["files"][0]["name"] = "../../escape.txt"
    raw = json.dumps(hdr, sort_keys=True).encode()
    evil = MAGIC + _HLEN.pack(len(raw)) + raw \
        + data[len(MAGIC) + 8 + hlen:]
    (tmp_path / "evil.shard").write_bytes(evil)
    with pytest.raises(EnvelopeError, match="escapes"):
        verify_envelope(str(tmp_path / "evil.shard"))


# ---------------------------------------------------------------------------
# Journal
# ---------------------------------------------------------------------------
def test_journal_semantics(tmp_path):
    j = Journal.load(str(tmp_path))          # absent -> empty
    assert j.applied == {} and j.generation == 0
    j2 = j.with_applied({"a": "sha_a"})
    j3 = j2.with_applied({"b": "sha_b"})
    assert "a" in j3 and "b" in j3 and "c" not in j3
    assert j3.generation == 2
    assert not j3.conflict("a", "sha_a")
    assert j3.conflict("a", "sha_OTHER")
    assert not j3.conflict("zzz", "whatever")   # unknown id: no conflict
    (tmp_path / JOURNAL_NAME).write_bytes(j3.dumps())
    assert Journal.load(str(tmp_path)) == j3
    (tmp_path / JOURNAL_NAME).write_text('{"version": 99, "applied": {}}')
    with pytest.raises(ValueError, match="version"):
        Journal.load(str(tmp_path))


# ---------------------------------------------------------------------------
# Daemon: the byte-identity + exactly-once contract
# ---------------------------------------------------------------------------
def test_fleet_fold_is_byte_identical_to_one_shot(tmp_path):
    shard_dbs, ref = build_fleet_inputs(tmp_path)
    daemon = fresh_daemon(tmp_path)
    producer = fresh_producer(tmp_path, daemon)
    for i, db in enumerate(shard_dbs):
        producer.stage(db, epoch=i)
    rep = producer.deliver()
    assert len(rep.delivered) == 3 and not rep.failed
    r = daemon.poll_once()
    assert sorted(r.applied) == sorted(
        Journal.load(daemon.db_dir).applied)
    assert_db_identical(daemon.db_dir, ref)
    # the journal rides inside the database directory
    assert os.path.exists(os.path.join(daemon.db_dir, JOURNAL_NAME))


def test_duplicate_deliveries_are_no_ops(tmp_path):
    shard_dbs, ref = build_fleet_inputs(tmp_path)
    daemon = fresh_daemon(tmp_path)
    producer = fresh_producer(tmp_path, daemon)
    for db in shard_dbs:
        producer.stage(db)
    producer.deliver()
    daemon.poll_once()
    before = db_bytes(daemon.db_dir)
    for _ in range(2):                       # re-deliver everything twice
        for db in shard_dbs:
            producer.stage(db)
        producer.deliver()
        r = daemon.poll_once()
        assert len(r.duplicates) == 3 and not r.applied
    assert db_bytes(daemon.db_dir) == before
    assert_db_identical(daemon.db_dir, ref)
    assert Journal.load(daemon.db_dir).generation == 1


def test_incremental_folds_match_one_shot(tmp_path):
    """Shards arriving across separate polls fold to the same bytes as
    all-at-once (the incremental-merge contract carried to the fleet)."""
    shard_dbs, ref = build_fleet_inputs(tmp_path)
    daemon = fresh_daemon(tmp_path)
    producer = fresh_producer(tmp_path, daemon)
    for db in shard_dbs:
        producer.stage(db)
        producer.deliver()
        daemon.poll_once()
    assert_db_identical(daemon.db_dir, ref)
    assert Journal.load(daemon.db_dir).generation == 3


def test_torn_and_corrupt_envelopes_quarantine(tmp_path):
    shard_dbs, ref = build_fleet_inputs(tmp_path)
    daemon = fresh_daemon(tmp_path)
    producer = fresh_producer(tmp_path, daemon)
    for db in shard_dbs:
        producer.stage(db)
    producer.deliver()
    env = tmp_path / "good.shard"
    pack_envelope(shard_dbs[0], str(env), shard_id="torn-one")
    data = env.read_bytes()
    incoming = Path(daemon.incoming_dir)
    (incoming / "torn.shard").write_bytes(data[: len(data) - 9])
    (incoming / "junk.shard").write_bytes(b"RUBBISH")
    r = daemon.poll_once()
    assert len(r.applied) == 3
    assert len(r.quarantined) == 2
    qdir = Path(daemon.quarantine_dir)
    names = {f.name for f in qdir.iterdir()}
    assert "torn.shard" in names and "junk.shard" in names
    assert (qdir / "torn.shard.reason").read_text().strip()
    assert_db_identical(daemon.db_dir, ref)   # the fold was unharmed


def test_shard_id_conflict_quarantines(tmp_path):
    db0, _, _ = build_shard(tmp_path, 0)
    db1, _, _ = build_shard(tmp_path, 1)
    daemon = fresh_daemon(tmp_path)
    a = str(tmp_path / "a.shard")
    b = str(tmp_path / "b.shard")
    pack_envelope(db0, a, shard_id="same-id")
    pack_envelope(db1, b, shard_id="same-id")   # different bytes!
    incoming = Path(daemon.incoming_dir)
    (incoming / "a.shard").write_bytes(Path(a).read_bytes())
    daemon.poll_once()
    (incoming / "b.shard").write_bytes(Path(b).read_bytes())
    r = daemon.poll_once()
    assert not r.applied and len(r.quarantined) == 1
    assert "different payload" in r.quarantined[0][1]
    want = str(tmp_path / "want")
    aggregate([], want)
    assert len(Journal.load(daemon.db_dir).applied) == 1


def test_metric_taxonomy_mismatch_quarantines(tmp_path):
    shard_dbs, ref = build_fleet_inputs(tmp_path)
    # a shard measured with a disjoint metric registry
    reg = MetricRegistry()
    weird = reg.register_kind("weird", ("zaps",))
    cct = CCT()
    node = cct.insert_path([Frame(HOST, "main", "app.py", 1)])
    node.metrics.add(weird, "zaps", 7.0)
    mdir = tmp_path / "modd"
    mdir.mkdir()
    p = str(mdir / "r99.rpro")
    write_profile(p, cct, reg, {"rank": 99, "type": "cpu"}, [])
    odd_db = str(tmp_path / "odd")
    aggregate([p], odd_db)
    daemon = fresh_daemon(tmp_path)
    producer = fresh_producer(tmp_path, daemon)
    for db in shard_dbs:
        producer.stage(db)
    producer.stage(odd_db)
    producer.deliver()
    r = daemon.poll_once()
    assert len(r.applied) == 3
    assert len(r.quarantined) == 1
    assert "metric taxonomy" in r.quarantined[0][1]
    assert_db_identical(daemon.db_dir, ref)


def test_bootstrap_taxonomy_is_majority_not_id_order(tmp_path):
    """Bootstrapping an EMPTY fleet db, the taxonomy reference is the
    batch majority — shard ids are content hashes, so any id-order rule
    would let an arbitrary outlier shard win the database (this flaked
    ~10% of runs before the majority vote: envelope bytes embed staging
    paths, so ids permute run to run)."""
    def one_round(sub, odd_first):
        sub.mkdir()
        shard_dbs, ref = build_fleet_inputs(sub, n_shards=2)
        reg = MetricRegistry()
        weird = reg.register_kind("weird", ("zaps",))
        cct = CCT()
        cct.insert_path([Frame(HOST, "main", "app.py", 1)]).metrics.add(
            weird, "zaps", 7.0)
        p = str(sub / "r99.rpro")
        write_profile(p, cct, reg, {"rank": 99, "type": "cpu"}, [])
        odd_db = str(sub / "odd")
        aggregate([p], odd_db)
        daemon = fresh_daemon(sub)
        producer = fresh_producer(sub, daemon)
        order = [odd_db] + shard_dbs if odd_first else shard_dbs + [odd_db]
        for db in order:
            producer.stage(db)
        producer.deliver()
        r = daemon.poll_once()
        assert len(r.applied) == 2
        assert len(r.quarantined) == 1
        assert "metric taxonomy" in r.quarantined[0][1]
        assert_db_identical(daemon.db_dir, ref)

    one_round(tmp_path / "odd_first", True)
    one_round(tmp_path / "odd_last", False)


def test_daemon_fold_applies_retention(tmp_path):
    """Retention at fold time composes with the journal (both commit in
    the same swap)."""
    from test_retention import write_epoch
    (tmp_path / "e1").mkdir()
    (tmp_path / "e2").mkdir()
    paths1 = write_epoch(tmp_path / "e1", 1)
    paths2 = write_epoch(tmp_path / "e2", 2)
    from test_merge import traces_of
    db1, db2 = str(tmp_path / "s1"), str(tmp_path / "s2")
    aggregate(paths1, db1, trace_paths=traces_of(paths1))
    aggregate(paths2, db2, trace_paths=traces_of(paths2))
    daemon = fresh_daemon(tmp_path,
                          retention=RetentionPolicy(keep_last_epochs=1))
    producer = fresh_producer(tmp_path, daemon)
    for db in (db1, db2):
        producer.stage(db)
        producer.deliver()
        daemon.poll_once()
    want = str(tmp_path / "want")
    aggregate(paths2, want, trace_paths=traces_of(paths2))
    assert_db_identical(daemon.db_dir, want)
    assert len(Journal.load(daemon.db_dir).applied) == 2


def test_daemon_status_and_run(tmp_path):
    shard_dbs, _ = build_fleet_inputs(tmp_path, n_shards=2)
    daemon = fresh_daemon(tmp_path)
    producer = fresh_producer(tmp_path, daemon)
    for db in shard_dbs:
        producer.stage(db)
    producer.deliver()
    assert daemon.run(interval_s=0.0, max_polls=2) == 2
    s = daemon.status()
    assert s["applied_shards"] == 2 and s["generation"] == 1
    assert s["pending"] == [] and s["incoming"] == []
    assert s["profiles"] == 6 and s["contexts"] > 1


# ---------------------------------------------------------------------------
# Producer client: bounded spool, backoff, never block
# ---------------------------------------------------------------------------
class FlakyTransport:
    """Fails the first ``n_failures`` sends, then delegates."""

    def __init__(self, inner, n_failures):
        self.inner = inner
        self.left = n_failures
        self.attempts = 0

    def send(self, path):
        self.attempts += 1
        if self.left > 0:
            self.left -= 1
            raise TransportError("injected transport failure")
        self.inner.send(path)


def test_deliver_retries_with_restart_policy_backoff(tmp_path):
    db, _, _ = build_shard(tmp_path, 0)
    daemon = fresh_daemon(tmp_path)
    flaky = FlakyTransport(DirectoryTransport(daemon.incoming_dir), 3)
    sleeps = []
    producer = ShardProducer(
        str(tmp_path / "outbox"), flaky, producer="hostA",
        policy=RestartPolicy(backoff_base_s=1.0, backoff_max_s=8.0,
                             max_restarts=10),
        clock=lambda: 0.0, sleep=sleeps.append)
    producer.stage(db)
    rep = producer.deliver()
    assert rep.delivered and not rep.gave_up
    assert flaky.attempts == 4
    assert sleeps == [1.0, 2.0, 4.0]        # exponential backoff
    assert daemon.poll_once().applied


def test_deliver_gives_up_when_restart_budget_exhausted(tmp_path):
    db, _, _ = build_shard(tmp_path, 0)
    daemon = fresh_daemon(tmp_path)
    flaky = FlakyTransport(DirectoryTransport(daemon.incoming_dir), 99)
    producer = ShardProducer(
        str(tmp_path / "outbox"), flaky, producer="hostA",
        policy=RestartPolicy(backoff_base_s=0.0, max_restarts=3),
        clock=lambda: 0.0, sleep=lambda s: None)
    producer.stage(db)
    rep = producer.deliver()
    assert rep.gave_up and rep.failed and not rep.delivered
    # the envelope stays spooled for the next deliver()
    assert len(producer.spooled()) == 1


def test_staging_identical_payload_twice_collapses(tmp_path):
    """Content-addressed ids: re-staging the same measurement after a
    producer crash lands on the same envelope, not a duplicate."""
    db, _, _ = build_shard(tmp_path, 0, n_profiles=1)
    daemon = fresh_daemon(tmp_path)
    producer = fresh_producer(tmp_path, daemon)
    assert producer.stage(db) == producer.stage(db)
    assert len(producer.spooled()) == 1


def test_bounded_spool_drops_oldest_epoch_with_counted_warning(tmp_path):
    # distinct payloads per epoch (as real epochs are)
    dbs = [build_shard(tmp_path, i, n_profiles=1)[0] for i in range(6)]
    daemon = fresh_daemon(tmp_path)
    producer = fresh_producer(tmp_path, daemon, spool_soft=2,
                              spool_max=3)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for epoch, db in enumerate(dbs):
            producer.stage(db, epoch=epoch,
                           meta={"n": epoch})
    assert producer.dropped == 3
    assert any("spool_max" in str(w.message) for w in caught)
    spooled = producer.spooled()
    assert len(spooled) == 3
    # the *newest* epochs survive
    from repro.fleet.envelope import read_header
    epochs = sorted(read_header(p)[0].meta["epoch"] for p in spooled)
    assert epochs == [3, 4, 5]
    assert producer.throttled                # above the soft bound


# ---------------------------------------------------------------------------
# Socket transport
# ---------------------------------------------------------------------------
def test_socket_ingest_roundtrip(tmp_path):
    shard_dbs, ref = build_fleet_inputs(tmp_path, n_shards=2)
    daemon = fresh_daemon(tmp_path)
    sock = str(tmp_path / "fleet.sock")
    listener = SocketIngest(daemon, sock)
    listener.start()
    try:
        producer = ShardProducer(str(tmp_path / "outbox"),
                                 SocketTransport(sock),
                                 producer="hostA", sleep=lambda s: None)
        for db in shard_dbs:
            producer.stage(db)
        rep = producer.deliver()
        assert len(rep.delivered) == 2
        # garbage over the socket lands in quarantine, not a crash
        import socket as socket_mod
        import struct
        with socket_mod.socket(socket_mod.AF_UNIX,
                               socket_mod.SOCK_STREAM) as s:
            s.connect(sock)
            s.sendall(struct.pack("<Q", 7) + b"GARBAGE")
            assert s.makefile("rb").readline().startswith(b"OK")
    finally:
        listener.stop()
    r = daemon.poll_once()
    assert len(r.applied) == 2 and len(r.quarantined) == 1
    assert_db_identical(daemon.db_dir, ref)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_fleet_cli_send_daemon_status(tmp_path, capsys):
    from repro.fleet.cli import main as fleet_main
    shard_dbs, ref = build_fleet_inputs(tmp_path, n_shards=2)
    db = str(tmp_path / "fleet")
    spool = str(tmp_path / "spool")
    incoming = os.path.join(spool, "incoming")
    os.makedirs(incoming, exist_ok=True)
    rc = fleet_main(["send", *shard_dbs,
                     "--outbox", str(tmp_path / "outbox"),
                     "--to", incoming, "--producer", "hostA"])
    assert rc == 0
    assert "delivered 2" in capsys.readouterr().out
    rc = fleet_main(["daemon", db, "--spool", spool, "--interval", "0",
                     "--max-polls", "1", "--workers", "1"])
    assert rc == 0
    assert "applied 2" in capsys.readouterr().out
    assert_db_identical(db, ref)
    rc = fleet_main(["status", db, "--spool", spool])
    assert rc == 0
    status = json.loads(capsys.readouterr().out)
    assert status["applied_shards"] == 2 and status["pending"] == []
