"""Shared pytest wiring: the golden-file update flag.

``pytest --update-goldens`` rewrites the checked-in golden outputs under
``tests/goldens/`` from the current renderer output instead of comparing
against them (see tests/test_goldens.py).
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--update-goldens", action="store_true", default=False,
        help="rewrite tests/goldens/* from current output instead of "
             "comparing")


@pytest.fixture
def update_goldens(request):
    return request.config.getoption("--update-goldens")
